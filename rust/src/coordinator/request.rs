//! Request/response types of the serving coordinator.

use std::sync::mpsc;
use std::time::Instant;

use crate::error::Result;
use crate::kernels::Kernel;

/// Where the feature projection runs (the router's core decision).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PathKind {
    /// FP-32 XLA artifact
    Digital,
    /// simulated AIMC chip + digital post-processing artifact
    Analog,
}

impl PathKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            PathKind::Digital => "digital",
            PathKind::Analog => "analog",
        }
    }

    pub fn parse(s: &str) -> Option<PathKind> {
        match s {
            "digital" | "fp32" => Some(PathKind::Digital),
            "analog" | "hw" => Some(PathKind::Analog),
            _ => None,
        }
    }
}

/// Performer deployment variant (Table I rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PerfMode {
    Fp32,
    HwAttn,
    HwFull,
}

impl PerfMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            PerfMode::Fp32 => "fp32",
            PerfMode::HwAttn => "hw_attn",
            PerfMode::HwFull => "hw_full",
        }
    }

    pub fn parse(s: &str) -> Option<PerfMode> {
        match s {
            "fp32" => Some(PerfMode::Fp32),
            "hw_attn" => Some(PerfMode::HwAttn),
            "hw_full" => Some(PerfMode::HwFull),
            _ => None,
        }
    }
}

/// Batching lane: requests in one lane share an executable + path and can
/// be batched together.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Lane {
    Feature(KernelLane, PathLane),
    Performer(ModeLane),
}

// ordered newtype-ish mirrors (Kernel/PathKind don't derive Ord)
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum KernelLane {
    Rbf,
    ArcCos0,
    Softmax,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum PathLane {
    Digital,
    Analog,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ModeLane {
    Fp32,
    HwAttn,
    HwFull,
}

impl From<Kernel> for KernelLane {
    fn from(k: Kernel) -> Self {
        match k {
            Kernel::Rbf => KernelLane::Rbf,
            Kernel::ArcCos0 => KernelLane::ArcCos0,
            Kernel::Softmax => KernelLane::Softmax,
        }
    }
}

impl KernelLane {
    pub fn kernel(&self) -> Kernel {
        match self {
            KernelLane::Rbf => Kernel::Rbf,
            KernelLane::ArcCos0 => Kernel::ArcCos0,
            KernelLane::Softmax => Kernel::Softmax,
        }
    }
}

impl From<PathKind> for PathLane {
    fn from(p: PathKind) -> Self {
        match p {
            PathKind::Digital => PathLane::Digital,
            PathKind::Analog => PathLane::Analog,
        }
    }
}

impl From<PerfMode> for ModeLane {
    fn from(m: PerfMode) -> Self {
        match m {
            PerfMode::Fp32 => ModeLane::Fp32,
            PerfMode::HwAttn => ModeLane::HwAttn,
            PerfMode::HwFull => ModeLane::HwFull,
        }
    }
}

impl ModeLane {
    pub fn mode(&self) -> PerfMode {
        match self {
            ModeLane::Fp32 => PerfMode::Fp32,
            ModeLane::HwAttn => PerfMode::HwAttn,
            ModeLane::HwFull => PerfMode::HwFull,
        }
    }
}

/// Request payload.
#[derive(Clone, Debug)]
pub enum RequestBody {
    /// map one sample x (len d) to its feature vector z
    Features {
        kernel: Kernel,
        path: PathKind,
        x: Vec<f32>,
    },
    /// classify one token sequence with the Performer
    Performer { mode: PerfMode, tokens: Vec<i32> },
}

impl RequestBody {
    pub fn lane(&self) -> Lane {
        match self {
            RequestBody::Features { kernel, path, .. } => {
                Lane::Feature((*kernel).into(), (*path).into())
            }
            RequestBody::Performer { mode, .. } => Lane::Performer((*mode).into()),
        }
    }
}

/// Response payload.
#[derive(Clone, Debug)]
pub enum ResponseBody {
    Features(Vec<f32>),
    Class { label: usize, logits: Vec<f32> },
}

/// Full response with telemetry.
#[derive(Debug)]
pub struct Response {
    pub result: Result<ResponseBody>,
    /// end-to-end latency (enqueue -> reply), microseconds
    pub latency_us: f64,
    /// modelled AIMC energy of the analog portion, microjoules
    pub energy_uj: f64,
    /// batch this request was served in
    pub batch_size: usize,
}

/// An in-flight request.
pub struct Request {
    pub body: RequestBody,
    pub reply: mpsc::SyncSender<Response>,
    pub enqueued: Instant,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lanes_partition_requests() {
        let a = RequestBody::Features {
            kernel: Kernel::Rbf,
            path: PathKind::Analog,
            x: vec![0.0],
        };
        let b = RequestBody::Features {
            kernel: Kernel::Rbf,
            path: PathKind::Digital,
            x: vec![0.0],
        };
        let c = RequestBody::Performer { mode: PerfMode::Fp32, tokens: vec![] };
        assert_ne!(a.lane(), b.lane());
        assert_ne!(a.lane(), c.lane());
        assert_eq!(
            a.lane(),
            Lane::Feature(KernelLane::Rbf, PathLane::Analog)
        );
    }

    #[test]
    fn parse_roundtrips() {
        for p in [PathKind::Digital, PathKind::Analog] {
            assert_eq!(PathKind::parse(p.as_str()), Some(p));
        }
        for m in [PerfMode::Fp32, PerfMode::HwAttn, PerfMode::HwFull] {
            assert_eq!(PerfMode::parse(m.as_str()), Some(m));
        }
        assert_eq!(PathKind::parse("bogus"), None);
    }
}
