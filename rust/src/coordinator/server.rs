//! TCP server: newline-delimited JSON requests/responses, plus binary
//! frames ([`crate::wire::frame`]) on the same listener.
//!
//! Protocol detection is per request (see `docs/protocol.md`): a request
//! whose first byte is `0xB1` is a length-prefixed binary frame, any
//! other first byte starts a JSON line. `[serve] wire` (or `--wire`)
//! can force one encoding; the other then gets a typed error and the
//! connection closes. Both encodings share one hardening envelope —
//! `[serve] max_frame_bytes` caps a frame body / request line, and
//! `[serve] idle_timeout_s` bounds both idle connections and half-sent
//! requests (typed error + close, never a hung reader).
//!
//! JSON request lines:
//!   {"type":"features","kernel":"rbf","path":"analog","x":[...]}
//!   {"type":"performer","mode":"hw_attn","tokens":[...]}
//!   {"type":"attn_open"[,"path":"analog"|"fp32"]} -> open a streaming
//!       kernelized-attention session (per-head Ω lanes on the fleet)
//!   {"type":"attn_append","session":N,"q":[...],"k":[...],"v":[...]}
//!       -> stream one token; returns its attention output
//!   {"type":"attn_close","session":N} -> close, report streamed tokens
//!   {"type":"stats"}   -> per-lane latency/energy + per-chip fleet stats
//!                         + attention session counters
//!   {"type":"health"}  -> per-chip health states + control-plane events
//!   {"type":"metrics"} -> the full Prometheus-style text exposition,
//!                         escaped into one JSON string field
//!   {"type":"trace"[,"limit":N]} -> newest sampled per-request trace
//!       spans with their stage breakdown + sampling counters (limit is
//!       clamped to the configured ring size; must be a positive integer)
//!   {"type":"series"[,"name":PREFIX,"points":N]} -> bounded metric
//!       time-series rings: key list without "name", ring tails (newest
//!       N points, default 64) for keys matching the prefix with it
//!   {"type":"alerts"} -> SLO alert instances (rule, series, state,
//!       value, threshold) + the count currently firing
//!   {"type":"events"[,"since":N,"limit":K]} -> control-plane event
//!       journal entries with seq >= since (bounded ring: first_seq >
//!       since means entries were dropped)
//!   {"type":"drain","chip":N[,"undrain":true]} -> steer traffic off/on a chip
//!   {"type":"ping"}
//! Responses: {"ok":true, ...} | {"ok":false,"error":"..."}
//!
//! Data-plane replies (`features`/`performer`/`attn_append`) echo the
//! engine-assigned `request_id`, which is the key to find that request's
//! span in the `trace` output (when its id was sampled). Error replies
//! echo a client-supplied `request_id` field when the request line
//! parsed, so pipelined clients can correlate failures too.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::engine::{Engine, SessionsHandle, StatsHandle, Submitter};
use super::request::{PathKind, PerfMode, RequestBody, ResponseBody};
use crate::config::json::{arr, num, obj, s, Json};
use crate::error::{Error, Result};
use crate::kernels::Kernel;
use crate::obsv::AlertState;
use crate::wire::frame::{WireReply, WireRequest};
use crate::wire::{scan_control_line, WireConfig, WireMode, MAGIC_REQUEST, PREFIX_LEN};

/// Running server (owns the engine).
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    engine: Option<Engine>,
}

impl Server {
    /// Bind + serve in background threads.
    pub fn start(engine: Engine, bind: &str) -> Result<Server> {
        let listener = TcpListener::bind(bind)
            .map_err(|e| Error::Coordinator(format!("bind {bind}: {e}")))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let submitter = engine.submitter();
        let stats = engine.stats_handle();
        let sessions = engine.sessions_handle();
        let wire = engine.wire_config();
        let accept_thread = std::thread::spawn(move || {
            let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
            while !stop2.load(Ordering::Relaxed) {
                // reap handles of connections that already hung up, so a
                // long-lived server doesn't accumulate one JoinHandle per
                // connection it ever accepted
                conns.retain(|c| !c.is_finished());
                match listener.accept() {
                    Ok((stream, _)) => {
                        let sub = submitter.clone();
                        let stats_c = stats.clone();
                        let sessions_c = sessions.clone();
                        let stop_c = stop2.clone();
                        let wire_c = wire.clone();
                        conns.push(std::thread::spawn(move || {
                            let _ = handle_conn(stream, sub, stats_c, sessions_c, stop_c, wire_c);
                        }));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            // handlers poll the stop flag via their read timeout, so this
            // join completes within one timeout interval even with
            // clients still connected
            for c in conns {
                let _ = c.join();
            }
        });
        Ok(Server { addr, stop, accept_thread: Some(accept_thread), engine: Some(engine) })
    }

    pub fn submitter(&self) -> Submitter {
        self.engine.as_ref().unwrap().submitter()
    }

    pub fn engine(&self) -> &Engine {
        self.engine.as_ref().unwrap()
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        if let Some(e) = self.engine.take() {
            e.shutdown();
        }
    }
}

/// Outcome of a blocking-with-deadline read helper. The connection's
/// 200ms read timeout is what turns the blocking reads into a poll loop
/// (for the stop flag and the deadline); `WouldBlock`/`TimedOut` never
/// escape these helpers.
enum ReadOutcome {
    Done,
    /// peer closed mid-request
    Eof,
    /// server is shutting down
    Stop,
    /// deadline passed without the request completing
    TimedOut,
    Err(std::io::Error),
}

fn is_poll_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// Fill `out` exactly, polling stop/deadline across short read timeouts
/// (`read_exact` would mis-handle `WouldBlock` on a timeout socket).
fn read_full(
    reader: &mut BufReader<TcpStream>,
    out: &mut [u8],
    deadline: Instant,
    stop: &AtomicBool,
) -> ReadOutcome {
    let mut filled = 0;
    while filled < out.len() {
        if stop.load(Ordering::Relaxed) {
            return ReadOutcome::Stop;
        }
        match reader.read(&mut out[filled..]) {
            Ok(0) => return ReadOutcome::Eof,
            Ok(n) => filled += n,
            Err(e) if is_poll_timeout(&e) => {
                if Instant::now() >= deadline {
                    return ReadOutcome::TimedOut;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return ReadOutcome::Err(e),
        }
    }
    ReadOutcome::Done
}

enum LineOutcome {
    /// a full `\n`-terminated line is in the buffer (newline excluded)
    Line,
    /// peer closed; the buffer holds a final unterminated line
    EofLine,
    /// the line exceeded `max` bytes before its newline arrived
    Oversize,
    Stop,
    TimedOut,
    Err(std::io::Error),
}

/// Accumulate one request line with a hard length cap and a deadline,
/// so a connection can neither grow an unbounded buffer nor hang the
/// handler with a newline that never comes.
fn read_bounded_line(
    reader: &mut BufReader<TcpStream>,
    buf: &mut Vec<u8>,
    max: usize,
    deadline: Instant,
    stop: &AtomicBool,
) -> LineOutcome {
    loop {
        if stop.load(Ordering::Relaxed) {
            return LineOutcome::Stop;
        }
        match reader.fill_buf() {
            Ok([]) => return LineOutcome::EofLine,
            Ok(avail) => match avail.iter().position(|&b| b == b'\n') {
                Some(i) => {
                    if buf.len() + i > max {
                        return LineOutcome::Oversize;
                    }
                    buf.extend_from_slice(&avail[..i]);
                    reader.consume(i + 1);
                    return LineOutcome::Line;
                }
                None => {
                    if buf.len() + avail.len() > max {
                        return LineOutcome::Oversize;
                    }
                    let n = avail.len();
                    buf.extend_from_slice(avail);
                    reader.consume(n);
                }
            },
            Err(e) if is_poll_timeout(&e) => {
                if Instant::now() >= deadline {
                    return LineOutcome::TimedOut;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return LineOutcome::Err(e),
        }
    }
}

/// Terminal JSON error: best-effort write (the connection closes next).
fn send_json_error(writer: &mut TcpStream, msg: &str) {
    let reply = obj(vec![("ok", Json::Bool(false)), ("error", s(msg))]);
    let _ = writer.write_all(reply.to_string().as_bytes());
    let _ = writer.write_all(b"\n");
}

/// Terminal binary error: best-effort write (the connection closes next).
fn send_binary_error(writer: &mut TcpStream, verb: u8, request_id: u64, msg: &str) {
    let (mut head, mut body) = (Vec::new(), Vec::new());
    WireReply::Err { verb, request_id, message: msg.to_string() }.encode_into(&mut head, &mut body);
    let _ = write_all_vectored(writer, &head, &body);
}

/// Terminal error in whichever encoding the connection's mode implies
/// (used where no request prefix chose one, e.g. the idle timeout).
fn send_mode_error(writer: &mut TcpStream, wire: &WireConfig, msg: &str) {
    if wire.mode == WireMode::Binary {
        send_binary_error(writer, 0, 0, msg);
    } else {
        send_json_error(writer, msg);
    }
}

/// One vectored write for prefix + body, with a fallback loop for
/// partial writes (`write_vectored` is best-effort, not all-or-nothing).
fn write_all_vectored(w: &mut TcpStream, head: &[u8], body: &[u8]) -> std::io::Result<()> {
    use std::io::IoSlice;
    let total = head.len() + body.len();
    let mut written = w.write_vectored(&[IoSlice::new(head), IoSlice::new(body)])?;
    while written < total {
        let n = if written < head.len() {
            w.write(&head[written..])?
        } else {
            w.write(&body[written - head.len()..])?
        };
        if n == 0 {
            return Err(std::io::ErrorKind::WriteZero.into());
        }
        written += n;
    }
    Ok(())
}

fn handle_conn(
    stream: TcpStream,
    sub: Submitter,
    stats: StatsHandle,
    sessions: SessionsHandle,
    stop: Arc<AtomicBool>,
    wire: WireConfig,
) -> std::io::Result<()> {
    stream.set_nodelay(true).ok();
    // periodic read timeout lets the handler notice server shutdown even
    // while a client holds the connection open without sending
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    // per-connection scratch, reused across requests: the JSON line
    // buffer, the binary frame body, and the reply prefix + body the
    // vectored writes send from
    let mut line: Vec<u8> = Vec::new();
    let mut frame_body: Vec<u8> = Vec::new();
    let mut head: Vec<u8> = Vec::new();
    let mut reply_body: Vec<u8> = Vec::new();
    loop {
        // ---- sniff the first byte of the next request -------------------
        let idle_deadline = Instant::now() + wire.idle_timeout;
        let first = loop {
            if stop.load(Ordering::Relaxed) {
                return Ok(());
            }
            match reader.fill_buf() {
                Ok([]) => return Ok(()), // EOF between requests
                Ok(avail) => {
                    // skip request separators / blank lines
                    let skip = avail.iter().take_while(|&&b| b == b'\n' || b == b'\r').count();
                    if skip > 0 {
                        reader.consume(skip);
                        continue;
                    }
                    break avail[0];
                }
                Err(e) if is_poll_timeout(&e) => {
                    if Instant::now() >= idle_deadline {
                        let msg = format!(
                            "idle timeout: no request in {:.0}s (serve.idle_timeout_s)",
                            wire.idle_timeout.as_secs_f64()
                        );
                        send_mode_error(&mut writer, &wire, &msg);
                        return Ok(());
                    }
                    continue;
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        };
        // a started request must complete within the same window
        let deadline = Instant::now() + wire.idle_timeout;

        if first == MAGIC_REQUEST {
            // ---- binary frame ------------------------------------------
            if wire.mode == WireMode::Json {
                send_json_error(
                    &mut writer,
                    "binary frame rejected: this listener is configured for \
                     newline-JSON only (serve.wire = \"json\")",
                );
                return Ok(());
            }
            let mut prefix = [0u8; PREFIX_LEN];
            match read_full(&mut reader, &mut prefix, deadline, &stop) {
                ReadOutcome::Done => {}
                ReadOutcome::Eof | ReadOutcome::Stop => return Ok(()),
                ReadOutcome::TimedOut => {
                    send_binary_error(&mut writer, 0, 0, "timed out mid-frame (prefix)");
                    return Ok(());
                }
                ReadOutcome::Err(e) => return Err(e),
            }
            let flags = u16::from_le_bytes(prefix[2..4].try_into().unwrap());
            if flags != 0 {
                send_binary_error(
                    &mut writer,
                    prefix[1],
                    0,
                    &format!("unsupported frame flags 0x{flags:04x}"),
                );
                return Ok(());
            }
            let len = u32::from_le_bytes(prefix[4..8].try_into().unwrap()) as usize;
            if len > wire.max_frame_bytes {
                send_binary_error(
                    &mut writer,
                    prefix[1],
                    0,
                    &format!(
                        "frame body of {len} bytes exceeds serve.max_frame_bytes ({})",
                        wire.max_frame_bytes
                    ),
                );
                return Ok(());
            }
            frame_body.resize(len, 0);
            match read_full(&mut reader, &mut frame_body, deadline, &stop) {
                ReadOutcome::Done => {}
                ReadOutcome::Eof | ReadOutcome::Stop => return Ok(()),
                ReadOutcome::TimedOut => {
                    send_binary_error(&mut writer, prefix[1], 0, "timed out mid-frame (body)");
                    return Ok(());
                }
                ReadOutcome::Err(e) => return Err(e),
            }
            // decode is the binary path's parse stage: raw little-endian
            // runs straight into batch-ready buffers
            let t_parse = Instant::now();
            let decoded = WireRequest::decode_body(prefix[1], &frame_body);
            let parse_us = t_parse.elapsed().as_secs_f64() * 1e6;
            let reply = match decoded {
                Ok(req) => dispatch_binary(req, parse_us, &sub, &sessions),
                Err(e) => {
                    // enough of the body to carry a correlation id?
                    // echo it, like JSON errors echo `request_id`
                    let rid = frame_body
                        .get(..8)
                        .map(|b| u64::from_le_bytes(b.try_into().unwrap()))
                        .unwrap_or(0);
                    WireReply::Err { verb: prefix[1], request_id: rid, message: e.to_string() }
                }
            };
            let t_ser = Instant::now();
            reply.encode_into(&mut head, &mut reply_body);
            let ser_us = t_ser.elapsed().as_secs_f64() * 1e6;
            stats.record_serialize(Some(reply.request_id()), ser_us);
            write_all_vectored(&mut writer, &head, &reply_body)?;
        } else {
            // ---- JSON line ---------------------------------------------
            if wire.mode == WireMode::Binary {
                send_binary_error(
                    &mut writer,
                    0,
                    0,
                    "JSON line rejected: this listener is configured for \
                     binary frames only (serve.wire = \"binary\")",
                );
                return Ok(());
            }
            line.clear();
            match read_bounded_line(&mut reader, &mut line, wire.max_frame_bytes, deadline, &stop)
            {
                LineOutcome::Line | LineOutcome::EofLine => {}
                LineOutcome::Oversize => {
                    send_json_error(
                        &mut writer,
                        &format!(
                            "request line exceeds serve.max_frame_bytes ({})",
                            wire.max_frame_bytes
                        ),
                    );
                    return Ok(());
                }
                LineOutcome::Stop => return Ok(()),
                LineOutcome::TimedOut => {
                    send_json_error(&mut writer, "timed out mid-line (no terminating newline)");
                    return Ok(());
                }
                LineOutcome::Err(e) => return Err(e),
            }
            let text = match std::str::from_utf8(&line) {
                Ok(t) => t,
                Err(_) => {
                    send_json_error(&mut writer, "request line is not valid UTF-8");
                    return Ok(());
                }
            };
            if text.trim().is_empty() {
                continue;
            }
            let reply = handle_line(text, &sub, &stats, &sessions);
            let t_ser = Instant::now();
            let out = reply.to_string();
            let ser_us = t_ser.elapsed().as_secs_f64() * 1e6;
            let rid = reply.get("request_id").and_then(|v| v.as_f64()).map(|f| f as u64);
            stats.record_serialize(rid, ser_us);
            writer.write_all(out.as_bytes())?;
            writer.write_all(b"\n")?;
        }
    }
}

/// Parse one request line, dispatch, serialize the reply. The JSON parse
/// is timed and attached to data-plane requests as their span's `parse`
/// stage.
///
/// Small control verbs take the lazy path-scanner
/// ([`scan_control_line`]) first: it extracts only the handful of keys
/// control dispatch reads, without building a `Json` tree for the rest
/// of the line. Data-plane lines (with their large numeric arrays) and
/// anything the scanner is unsure about fall back to the full parser.
pub fn handle_line(
    line: &str,
    sub: &Submitter,
    stats: &StatsHandle,
    sessions: &SessionsHandle,
) -> Json {
    let t_parse = std::time::Instant::now();
    let parsed = match scan_control_line(line) {
        Some(j) => Ok(j),
        None => Json::parse(line),
    };
    let parse_us = t_parse.elapsed().as_secs_f64() * 1e6;
    let (request_id, result) = match parsed {
        Ok(req) => {
            // a client-supplied correlation id is echoed even on errors
            let id = req.get("request_id").cloned();
            (id, dispatch(&req, parse_us, sub, stats, sessions))
        }
        Err(e) => (None, Err(e)),
    };
    match result {
        Ok(j) => j,
        Err(e) => {
            let mut fields = vec![("ok", Json::Bool(false)), ("error", s(&e.to_string()))];
            if let Some(id) = request_id {
                fields.push(("request_id", id));
            }
            obj(fields)
        }
    }
}

/// The `stats` response: per-lane serving telemetry plus per-chip fleet
/// utilization, queue depth and recalibration counters, plus aggregate
/// attention-session counters.
fn stats_json(stats: &StatsHandle, sessions: &SessionsHandle) -> Json {
    let lanes = stats.lanes().into_iter().map(|l| {
        obj(vec![
            ("lane", s(&l.lane.label())),
            ("requests", num(l.requests as f64)),
            ("errors", num(l.errors as f64)),
            ("p50_us", num(l.p50_us)),
            ("p95_us", num(l.p95_us)),
            ("p99_us", num(l.p99_us)),
            ("mean_batch", num(l.mean_batch)),
            ("energy_uj", num(l.energy_uj)),
        ])
    });
    let chips = stats.chips().into_iter().map(|c| {
        obj(vec![
            ("chip", num(c.chip as f64)),
            ("health", s(c.health)),
            ("cores_used", num(c.cores_used as f64)),
            ("utilization", num(c.utilization)),
            ("queue_depth", num(c.queue_depth as f64)),
            ("busy_cores", num(c.busy_cores as f64)),
            ("core_utilization", num(c.core_utilization)),
            ("core_oversubscription", num(c.core_oversubscription)),
            ("served", num(c.served as f64)),
            ("errors", num(c.errors as f64)),
            ("recals", num(c.recals as f64)),
            ("age_s", num(c.age_s)),
            ("drift_err_estimate", num(c.drift_err_estimate)),
        ])
    });
    let sess = sessions.stats();
    obj(vec![
        ("ok", Json::Bool(true)),
        ("total_requests", num(stats.total_requests() as f64)),
        (
            "fleet",
            obj(vec![
                ("n_chips", num(stats.n_chips() as f64)),
                ("total_slots", num(stats.total_slots() as f64)),
                ("cores_used", num(stats.cores_used() as f64)),
                ("utilization", num(stats.utilization())),
                ("inflight", num(stats.total_inflight() as f64)),
            ]),
        ),
        (
            "attention",
            obj(vec![
                ("active_sessions", num(sess.active as f64)),
                ("opened", num(sess.opened as f64)),
                ("closed", num(sess.closed as f64)),
                ("tokens", num(sess.tokens as f64)),
            ]),
        ),
        ("lanes", arr(lanes)),
        ("chips", arr(chips)),
    ])
}

/// The `health` response: the control plane's view — per-chip health
/// states, error/probe counters, and fleet-wide event totals.
fn health_json(stats: &StatsHandle) -> Json {
    let chips = stats.chips().into_iter().map(|c| {
        obj(vec![
            ("chip", num(c.chip as f64)),
            ("health", s(c.health)),
            ("queue_depth", num(c.queue_depth as f64)),
            ("busy_cores", num(c.busy_cores as f64)),
            ("core_utilization", num(c.core_utilization)),
            ("core_oversubscription", num(c.core_oversubscription)),
            ("errors", num(c.errors as f64)),
            ("recals", num(c.recals as f64)),
            ("age_s", num(c.age_s)),
            ("drift_err_estimate", num(c.drift_err_estimate)),
        ])
    });
    let ev = stats.fleet_events();
    obj(vec![
        ("ok", Json::Bool(true)),
        ("control_enabled", Json::Bool(stats.control_enabled())),
        ("n_chips", num(stats.n_chips() as f64)),
        ("total_slots", num(stats.total_slots() as f64)),
        (
            "events",
            obj(vec![
                ("evictions", num(ev.evictions as f64)),
                ("scale_ups", num(ev.scale_ups as f64)),
                ("scale_downs", num(ev.scale_downs as f64)),
                ("drains", num(ev.drains as f64)),
            ]),
        ),
        ("chips", arr(chips)),
    ])
}

/// Render a reply value that may be NaN (never-served gauges): JSON has
/// no NaN, so non-finite values become null.
fn fin(v: f64) -> Json {
    if v.is_finite() {
        num(v)
    } else {
        Json::Null
    }
}

/// Parse an optional non-negative integer field. Typed error on
/// negatives, fractions, non-numbers and absurd magnitudes — `as usize`
/// must never wrap or truncate a bad value into a plausible one.
fn opt_index(req: &Json, key: &str) -> Result<Option<usize>> {
    match req.get(key) {
        None => Ok(None),
        Some(v) => {
            let raw = v
                .as_f64()
                .ok_or_else(|| Error::Parse(format!("{key} must be a number")))?;
            if raw < 0.0 || raw.fract() != 0.0 || raw > u32::MAX as f64 {
                return Err(Error::Parse(format!(
                    "{key} must be a non-negative integer (at most {}), got {raw}",
                    u32::MAX
                )));
            }
            Ok(Some(raw as usize))
        }
    }
}

/// Parse a required JSON array of numbers into f32s (typed error on a
/// missing key or non-numeric elements).
fn f32_array(req: &Json, key: &str) -> Result<Vec<f32>> {
    req.req(key)?
        .as_arr()
        .ok_or_else(|| Error::Parse(format!("{key} must be an array")))?
        .iter()
        .map(|v| {
            v.as_f64()
                .map(|f| f as f32)
                .ok_or_else(|| Error::Parse(format!("{key} must contain numbers")))
        })
        .collect()
}

fn dispatch(
    req: &Json,
    parse_us: f64,
    sub: &Submitter,
    stats: &StatsHandle,
    sessions: &SessionsHandle,
) -> Result<Json> {
    let ty = req.req_str("type")?;
    match ty {
        "ping" => Ok(obj(vec![("ok", Json::Bool(true)), ("pong", Json::Bool(true))])),
        "stats" => Ok(stats_json(stats, sessions)),
        "health" => Ok(health_json(stats)),
        "metrics" => Ok(obj(vec![
            ("ok", Json::Bool(true)),
            ("metrics", s(&stats.metrics_text())),
        ])),
        "trace" => {
            // a limit of 0 is a typed error (a silent empty reply reads
            // as "no spans"); sane-but-large limits clamp to the ring
            // cap, which is the most `latest` can ever return anyway
            let limit = match opt_index(req, "limit")? {
                None => 16,
                Some(0) => {
                    return Err(Error::Parse("limit must be at least 1".into()));
                }
                Some(n) => n.min(stats.trace_cap()),
            };
            let (sample_every, sampled, dropped) = stats.trace_counts();
            let spans = stats.traces(limit).into_iter().map(|sp| {
                obj(vec![
                    ("request_id", num(sp.request_id as f64)),
                    ("lane", s(&sp.lane)),
                    ("batch", num(sp.batch as f64)),
                    ("ok", Json::Bool(sp.ok)),
                    ("parse_us", num(sp.parse_us)),
                    ("queue_us", num(sp.queue_us)),
                    ("dispatch_us", num(sp.dispatch_us)),
                    ("lock_wait_us", num(sp.lock_wait_us)),
                    ("analog_mvm_us", num(sp.analog_mvm_us)),
                    ("digital_combine_us", num(sp.digital_combine_us)),
                    ("serialize_us", num(sp.serialize_us)),
                    ("total_us", num(sp.total_us)),
                ])
            });
            Ok(obj(vec![
                ("ok", Json::Bool(true)),
                ("sample_every", num(sample_every as f64)),
                ("sampled", num(sampled as f64)),
                ("dropped", num(dropped as f64)),
                ("spans", arr(spans)),
            ]))
        }
        "attn_open" => {
            let path = match req.get("path").and_then(|p| p.as_str()) {
                Some(p) => Some(
                    PathKind::parse(p).ok_or_else(|| Error::Parse("bad path".into()))?,
                ),
                None => None,
            };
            let info = sessions.open(path)?;
            Ok(obj(vec![
                ("ok", Json::Bool(true)),
                ("session", num(info.id as f64)),
                ("path", s(info.path.as_str())),
                ("heads", num(info.heads as f64)),
                ("d_head", num(info.d_head as f64)),
                ("m", num(info.m as f64)),
            ]))
        }
        "attn_append" => {
            let session = req.req_usize("session")? as u64;
            let q = f32_array(req, "q")?;
            let k = f32_array(req, "k")?;
            let v = f32_array(req, "v")?;
            let resp = sub.call_parsed(RequestBody::AttnAppend { session, q, k, v }, parse_us)?;
            let body = resp.result?;
            match body {
                ResponseBody::AttnOut { y, index } => Ok(obj(vec![
                    ("ok", Json::Bool(true)),
                    ("session", num(session as f64)),
                    ("index", num(index as f64)),
                    ("y", arr(y.iter().map(|&v| num(v as f64)))),
                    ("latency_us", num(resp.latency_us)),
                    ("energy_uj", num(resp.energy_uj)),
                    ("batch", num(resp.batch_size as f64)),
                    ("request_id", num(resp.request_id as f64)),
                ])),
                _ => Err(Error::Coordinator("unexpected body".into())),
            }
        }
        "attn_close" => {
            let session = req.req_usize("session")? as u64;
            let tokens = sessions.close(session)?;
            Ok(obj(vec![
                ("ok", Json::Bool(true)),
                ("session", num(session as f64)),
                ("tokens", num(tokens as f64)),
            ]))
        }
        "drain" => {
            // state-changing verb: reject negatives/fractions instead of
            // letting `as usize` truncate them onto chip 0
            let raw = req
                .req("chip")?
                .as_f64()
                .ok_or_else(|| Error::Parse("chip must be an index".into()))?;
            if raw < 0.0 || raw.fract() != 0.0 {
                return Err(Error::Parse(format!(
                    "chip must be a non-negative integer, got {raw}"
                )));
            }
            let chip = raw as usize;
            let undrain = matches!(req.get("undrain"), Some(Json::Bool(true)));
            let state = if undrain {
                stats.undrain_chip(chip)?
            } else {
                stats.drain_chip(chip)?
            };
            Ok(obj(vec![
                ("ok", Json::Bool(true)),
                ("chip", num(chip as f64)),
                ("health", s(state.as_str())),
            ]))
        }
        "features" => {
            let kernel = Kernel::parse(req.req_str("kernel")?)
                .ok_or_else(|| Error::Parse("bad kernel".into()))?;
            let path = PathKind::parse(req.str_or("path", "digital"))
                .ok_or_else(|| Error::Parse("bad path".into()))?;
            let x: Vec<f32> = req
                .req("x")?
                .as_arr()
                .ok_or_else(|| Error::Parse("x must be an array".into()))?
                .iter()
                .filter_map(|v| v.as_f64().map(|f| f as f32))
                .collect();
            let resp = sub.call_parsed(RequestBody::Features { kernel, path, x }, parse_us)?;
            let body = resp.result?;
            match body {
                ResponseBody::Features(z) => Ok(obj(vec![
                    ("ok", Json::Bool(true)),
                    ("z", arr(z.iter().map(|&v| num(v as f64)))),
                    ("latency_us", num(resp.latency_us)),
                    ("energy_uj", num(resp.energy_uj)),
                    ("batch", num(resp.batch_size as f64)),
                    ("request_id", num(resp.request_id as f64)),
                ])),
                _ => Err(Error::Coordinator("unexpected body".into())),
            }
        }
        "performer" => {
            let mode = PerfMode::parse(req.str_or("mode", "fp32"))
                .ok_or_else(|| Error::Parse("bad mode".into()))?;
            let tokens: Vec<i32> = req
                .req("tokens")?
                .as_arr()
                .ok_or_else(|| Error::Parse("tokens must be an array".into()))?
                .iter()
                .filter_map(|v| v.as_f64().map(|f| f as i32))
                .collect();
            let resp = sub.call_parsed(RequestBody::Performer { mode, tokens }, parse_us)?;
            let body = resp.result?;
            match body {
                ResponseBody::Class { label, logits } => Ok(obj(vec![
                    ("ok", Json::Bool(true)),
                    ("label", num(label as f64)),
                    ("logits", arr(logits.iter().map(|&v| num(v as f64)))),
                    ("latency_us", num(resp.latency_us)),
                    ("energy_uj", num(resp.energy_uj)),
                    ("batch", num(resp.batch_size as f64)),
                    ("request_id", num(resp.request_id as f64)),
                ])),
                _ => Err(Error::Coordinator("unexpected body".into())),
            }
        }
        "series" => {
            let points = match opt_index(req, "points")? {
                None => 64,
                Some(0) => {
                    return Err(Error::Parse("points must be at least 1".into()));
                }
                Some(n) => n,
            };
            match req.get("name").and_then(|v| v.as_str()) {
                // no name: enumerate the keys so a client can discover
                // what to ask for
                None => Ok(obj(vec![
                    ("ok", Json::Bool(true)),
                    ("keys", arr(stats.series_keys("").into_iter().map(|k| s(&k)))),
                ])),
                Some(prefix) => {
                    let series = stats.series_keys(prefix).into_iter().map(|key| {
                        let pts = stats.series_points(&key, points);
                        obj(vec![
                            ("key", s(&key)),
                            (
                                "points",
                                arr(pts.iter().map(|p| {
                                    obj(vec![("t_s", num(p.t_s)), ("value", fin(p.value))])
                                })),
                            ),
                        ])
                    });
                    Ok(obj(vec![("ok", Json::Bool(true)), ("series", arr(series))]))
                }
            }
        }
        "alerts" => {
            let insts = stats.alerts();
            let firing = insts
                .iter()
                .filter(|a| a.state == AlertState::Firing)
                .count();
            Ok(obj(vec![
                ("ok", Json::Bool(true)),
                ("firing", num(firing as f64)),
                (
                    "alerts",
                    arr(insts.iter().map(|a| {
                        obj(vec![
                            ("rule", s(&a.rule)),
                            ("series", s(&a.series)),
                            ("state", s(a.state.as_str())),
                            ("value", fin(a.value)),
                            ("threshold", fin(a.threshold)),
                            ("since_t_s", num(a.since_t_s)),
                        ])
                    })),
                ),
            ]))
        }
        "events" => {
            let since = opt_index(req, "since")?.unwrap_or(0) as u64;
            let limit = match opt_index(req, "limit")? {
                None => 256,
                Some(0) => {
                    return Err(Error::Parse("limit must be at least 1".into()));
                }
                Some(n) => n,
            };
            let (events, first_seq, next_seq) = stats.events_since(since);
            Ok(obj(vec![
                ("ok", Json::Bool(true)),
                ("first_seq", num(first_seq as f64)),
                ("next_seq", num(next_seq as f64)),
                (
                    "events",
                    arr(events.iter().take(limit).map(|e| {
                        obj(vec![
                            ("seq", num(e.seq as f64)),
                            ("t_s", num(e.t_s)),
                            ("kind", s(&e.kind)),
                            ("detail", s(&e.detail)),
                        ])
                    })),
                ),
            ]))
        }
        other => Err(Error::Parse(format!("unknown request type '{other}'"))),
    }
}

/// Dispatch a decoded binary request. The f32 payloads decoded from the
/// frame body move into [`RequestBody`] unchanged — no re-copy between
/// the wire codec and the batcher. Errors echo the *client's*
/// correlation id; data-plane successes carry the engine-assigned id,
/// exactly like the JSON encoding.
fn dispatch_binary(
    req: WireRequest,
    parse_us: f64,
    sub: &Submitter,
    sessions: &SessionsHandle,
) -> WireReply {
    let verb = req.verb();
    let client_id = req.request_id();
    let result = (|| -> Result<WireReply> {
        match req {
            WireRequest::Ping { request_id } => Ok(WireReply::Pong { request_id }),
            WireRequest::AttnOpen { request_id, path } => {
                let info = sessions.open(path)?;
                Ok(WireReply::AttnOpened {
                    request_id,
                    session: info.id,
                    heads: info.heads as u32,
                    d_head: info.d_head as u32,
                    m: info.m as u32,
                    path: info.path,
                })
            }
            WireRequest::AttnClose { request_id, session } => {
                let tokens = sessions.close(session)?;
                Ok(WireReply::AttnClosed { request_id, session, tokens: tokens as u64 })
            }
            WireRequest::AttnAppend { session, q, k, v, .. } => {
                let resp =
                    sub.call_parsed(RequestBody::AttnAppend { session, q, k, v }, parse_us)?;
                let request_id = resp.request_id;
                match resp.result? {
                    ResponseBody::AttnOut { y, index } => Ok(WireReply::AttnOut {
                        request_id,
                        session,
                        index: index as u32,
                        latency_us: resp.latency_us,
                        energy_uj: resp.energy_uj,
                        batch: resp.batch_size as u32,
                        y,
                    }),
                    _ => Err(Error::Coordinator("unexpected body".into())),
                }
            }
            WireRequest::Features { kernel, path, x, .. } => {
                let resp = sub.call_parsed(RequestBody::Features { kernel, path, x }, parse_us)?;
                let request_id = resp.request_id;
                match resp.result? {
                    ResponseBody::Features(z) => Ok(WireReply::Features {
                        request_id,
                        latency_us: resp.latency_us,
                        energy_uj: resp.energy_uj,
                        batch: resp.batch_size as u32,
                        z,
                    }),
                    _ => Err(Error::Coordinator("unexpected body".into())),
                }
            }
            WireRequest::Performer { mode, tokens, .. } => {
                let resp = sub.call_parsed(RequestBody::Performer { mode, tokens }, parse_us)?;
                let request_id = resp.request_id;
                match resp.result? {
                    ResponseBody::Class { label, logits } => Ok(WireReply::Class {
                        request_id,
                        latency_us: resp.latency_us,
                        energy_uj: resp.energy_uj,
                        batch: resp.batch_size as u32,
                        label: label as u32,
                        logits,
                    }),
                    _ => Err(Error::Coordinator("unexpected body".into())),
                }
            }
        }
    })();
    result.unwrap_or_else(|e| WireReply::Err {
        verb,
        request_id: client_id,
        message: e.to_string(),
    })
}

/// Minimal blocking TCP client for the line protocol (examples + tests).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| Error::Coordinator(format!("connect {addr}: {e}")))?;
        stream.set_nodelay(true).ok();
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    pub fn call(&mut self, request: &Json) -> Result<Json> {
        self.writer.write_all(request.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn config() -> Config {
        let mut cfg = Config::default();
        cfg.artifacts_dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts")
            .to_string_lossy()
            .to_string();
        cfg.serve.max_wait_us = 500;
        cfg.serve.bind = "127.0.0.1:0".into();
        cfg.serve.warm = false;
        cfg
    }

    fn have_artifacts() -> bool {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json")
            .exists()
    }

    #[test]
    fn tcp_roundtrip_ping_features_performer() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let cfg = config();
        let engine = Engine::start(&cfg).unwrap();
        let seq_len = engine.seq_len().unwrap();
        let server = Server::start(engine, &cfg.serve.bind).unwrap();
        let mut client = Client::connect(&server.addr).unwrap();

        let pong = client.call(&Json::parse(r#"{"type":"ping"}"#).unwrap()).unwrap();
        assert_eq!(pong.get("ok"), Some(&Json::Bool(true)));

        let x: Vec<String> = (0..16).map(|i| format!("{}", (i as f64) / 16.0)).collect();
        let req = format!(
            r#"{{"type":"features","kernel":"rbf","path":"analog","x":[{}]}}"#,
            x.join(",")
        );
        let resp = client.call(&Json::parse(&req).unwrap()).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        assert_eq!(resp.get("z").unwrap().as_arr().unwrap().len(), 512);

        let mut rng = crate::util::Rng::new(0);
        let batch = crate::datasets::lra::gen_pattern(&mut rng, 1, seq_len);
        let toks: Vec<String> = batch.row(0).iter().map(|t| t.to_string()).collect();
        let req = format!(
            r#"{{"type":"performer","mode":"fp32","tokens":[{}]}}"#,
            toks.join(",")
        );
        let resp = client.call(&Json::parse(&req).unwrap()).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        let label = resp.get("label").unwrap().as_usize().unwrap();
        assert_eq!(label, batch.labels[0]);
        assert!(resp.get("request_id").unwrap().as_usize().unwrap() >= 1);

        // stats surfaces lanes + per-chip fleet counters
        let resp = client.call(&Json::parse(r#"{"type":"stats"}"#).unwrap()).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        assert!(resp.get("total_requests").unwrap().as_usize().unwrap() >= 2);
        let chips = resp.get("chips").unwrap().as_arr().unwrap();
        assert!(!chips.is_empty());
        assert!(chips[0].get("served").unwrap().as_usize().unwrap() >= 1);
        // lock-free core-parallelism gauges: idle between requests
        assert_eq!(chips[0].get("busy_cores").unwrap().as_usize(), Some(0));
        assert!(chips[0].get("core_utilization").is_some());
        assert_eq!(
            resp.get("fleet").unwrap().get("inflight").unwrap().as_usize(),
            Some(0)
        );
        assert!(!resp.get("lanes").unwrap().as_arr().unwrap().is_empty());

        // health verb: per-chip states + control-plane event counters
        let resp = client.call(&Json::parse(r#"{"type":"health"}"#).unwrap()).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        assert_eq!(resp.get("control_enabled"), Some(&Json::Bool(false)));
        let chips = resp.get("chips").unwrap().as_arr().unwrap();
        assert_eq!(chips[0].get("health").unwrap().as_str(), Some("healthy"));
        assert!(chips[0].get("busy_cores").is_some());
        assert!(chips[0].get("core_utilization").is_some());
        assert!(resp.get("events").unwrap().get("evictions").is_some());

        // drain steers the chip out of service; undrain restores it
        let resp = client
            .call(&Json::parse(r#"{"type":"drain","chip":0}"#).unwrap())
            .unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        assert_eq!(resp.get("health").unwrap().as_str(), Some("draining"));
        let resp = client
            .call(&Json::parse(r#"{"type":"drain","chip":0,"undrain":true}"#).unwrap())
            .unwrap();
        assert_eq!(resp.get("health").unwrap().as_str(), Some("healthy"));
        // draining a nonexistent chip is a clean error
        let resp = client
            .call(&Json::parse(r#"{"type":"drain","chip":99}"#).unwrap())
            .unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));

        // metrics verb: Prometheus text escaped into one JSON string
        let resp = client.call(&Json::parse(r#"{"type":"metrics"}"#).unwrap()).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        let text = resp.get("metrics").unwrap().as_str().unwrap();
        assert!(text.contains("imka_requests_total"));
        assert!(text.contains("imka_chip_core_utilization"));
        assert!(text.contains("imka_fleet_inflight"));

        // trace verb: sampling counters + span array (shape only here;
        // id propagation is pinned by the tests/attention_serve.rs suite)
        let resp = client.call(&Json::parse(r#"{"type":"trace"}"#).unwrap()).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        assert!(resp.get("sample_every").is_some());
        assert!(resp.get("spans").unwrap().as_arr().is_some());

        // unknown type -> clean error
        let resp = client.call(&Json::parse(r#"{"type":"wat"}"#).unwrap()).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));

        server.shutdown();
    }
}
