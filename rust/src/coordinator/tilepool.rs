//! Tile pool: owns the simulated chip, programs the mapping matrices of
//! each feature lane (with optional replication across spare cores), and
//! serializes analog MVMs.

use std::collections::BTreeMap;
use std::sync::Mutex;

use super::request::KernelLane;
use crate::aimc::{Chip, MatrixHandle};
use crate::config::ChipConfig;
use crate::error::{Error, Result};
use crate::linalg::Mat;
use crate::util::Rng;

/// One programmed feature-mapping matrix.
pub struct LaneMapping {
    pub handle: MatrixHandle,
    /// the FP-32 Ω (digital-path twin of the programmed weights)
    pub omega: Mat,
    pub d: usize,
    pub m: usize,
}

/// The chip + its programmed lanes.
pub struct TilePool {
    chip: Mutex<Chip>,
    lanes: BTreeMap<KernelLane, LaneMapping>,
}

impl TilePool {
    pub fn new(cfg: ChipConfig, seed: u64) -> TilePool {
        TilePool { chip: Mutex::new(Chip::new(cfg, seed)), lanes: BTreeMap::new() }
    }

    /// Program Ω for a feature lane. `x_cal` is a sample of (normalized)
    /// inputs used for DAC/ADC calibration; `replication` spreads copies
    /// over spare cores for throughput.
    pub fn program_lane(
        &mut self,
        lane: KernelLane,
        omega: Mat,
        x_cal: &Mat,
        replication: usize,
    ) -> Result<()> {
        if self.lanes.contains_key(&lane) {
            return Err(Error::Coordinator(format!("lane {lane:?} already programmed")));
        }
        let name = format!("omega_{}", lane.kernel().as_str());
        let mut chip = self.chip.lock().unwrap();
        let handle = chip.program_matrix(&name, &omega, x_cal, replication)?;
        drop(chip);
        let (d, m) = (omega.rows, omega.cols);
        self.lanes.insert(lane, LaneMapping { handle, omega, d, m });
        Ok(())
    }

    pub fn mapping(&self, lane: KernelLane) -> Result<&LaneMapping> {
        self.lanes
            .get(&lane)
            .ok_or_else(|| Error::Coordinator(format!("lane {lane:?} not programmed")))
    }

    /// Analog projection u = x·Ω on the chip.
    pub fn project(&self, lane: KernelLane, x: &Mat) -> Result<Mat> {
        let mapping = self.mapping(lane)?;
        let mut chip = self.chip.lock().unwrap();
        chip.matmul(&mapping.handle, x)
    }

    pub fn cores_used(&self) -> usize {
        self.chip.lock().unwrap().cores_used()
    }

    pub fn utilization(&self) -> f64 {
        self.chip.lock().unwrap().utilization()
    }

    /// Mean GDP programming error across a lane's tiles.
    pub fn programming_rms(&self, lane: KernelLane) -> Result<f64> {
        let mapping = self.mapping(lane)?;
        let chip = self.chip.lock().unwrap();
        let stats = chip
            .program_stats(&mapping.handle)
            .ok_or_else(|| Error::Coordinator("no stats".into()))?;
        Ok(stats.iter().map(|s| s.rms_final).sum::<f64>() / stats.len().max(1) as f64)
    }
}

/// Deterministic Ω generator for serving lanes.
pub fn lane_omega(lane: KernelLane, d: usize, m: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed ^ 0x0_4E6A ^ lane as u64);
    crate::features::sample_omega(crate::features::Sampler::Orf, d, m, &mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::rel_fro_error;

    #[test]
    fn program_and_project() {
        let mut pool = TilePool::new(ChipConfig::default(), 1);
        let mut rng = Rng::new(0);
        let omega = Mat::randn(16, 64, &mut rng);
        let x_cal = Mat::randn(32, 16, &mut rng);
        pool.program_lane(KernelLane::Rbf, omega.clone(), &x_cal, 1)
            .unwrap();
        assert_eq!(pool.cores_used(), 1);
        let x = Mat::randn(8, 16, &mut rng);
        let u = pool.project(KernelLane::Rbf, &x).unwrap();
        let want = crate::linalg::matmul(&x, &omega);
        let rel = rel_fro_error(&u.data, &want.data);
        assert!(rel > 0.0 && rel < 0.12, "rel {rel}");
        assert!(pool.programming_rms(KernelLane::Rbf).unwrap() < 0.05);
    }

    #[test]
    fn double_program_rejected() {
        let mut pool = TilePool::new(ChipConfig::default(), 2);
        let mut rng = Rng::new(1);
        let omega = Mat::randn(8, 8, &mut rng);
        let x = Mat::randn(8, 8, &mut rng);
        pool.program_lane(KernelLane::Softmax, omega.clone(), &x, 1)
            .unwrap();
        assert!(pool
            .program_lane(KernelLane::Softmax, omega, &x, 1)
            .is_err());
    }

    #[test]
    fn unprogrammed_lane_errors() {
        let pool = TilePool::new(ChipConfig::default(), 3);
        let x = Mat::zeros(1, 4);
        assert!(pool.project(KernelLane::ArcCos0, &x).is_err());
    }
}
