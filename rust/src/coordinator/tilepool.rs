//! Tile pool: owns the simulated chip, programs the mapping matrices of
//! each feature lane (with optional replication across spare cores), and
//! serializes analog MVMs.

use std::collections::BTreeMap;
use std::sync::Mutex;

use super::request::KernelLane;
use crate::aimc::{Chip, MatrixHandle};
use crate::config::ChipConfig;
use crate::error::{Error, Result};
use crate::linalg::Mat;
use crate::util::Rng;

/// One programmed feature-mapping matrix.
pub struct LaneMapping {
    pub handle: MatrixHandle,
    /// the FP-32 Ω (digital-path twin of the programmed weights)
    pub omega: Mat,
    pub d: usize,
    pub m: usize,
}

/// The chip + its programmed lanes.
pub struct TilePool {
    chip: Mutex<Chip>,
    lanes: BTreeMap<KernelLane, LaneMapping>,
}

impl TilePool {
    pub fn new(cfg: ChipConfig, seed: u64) -> TilePool {
        TilePool { chip: Mutex::new(Chip::new(cfg, seed)), lanes: BTreeMap::new() }
    }

    /// Program Ω for a feature lane. `x_cal` is a sample of (normalized)
    /// inputs used for DAC/ADC calibration; `replication` spreads copies
    /// over spare cores for throughput.
    ///
    /// Programming the same lane twice is a caller bug and returns a typed
    /// [`Error::Coordinator`] *before* touching the chip (the chip-level
    /// duplicate-name check never fires, so no cores are leaked to a
    /// half-programmed placement). Use [`TilePool::reprogram_lane`] when
    /// rewriting an existing lane is intended (recalibration).
    pub fn program_lane(
        &mut self,
        lane: KernelLane,
        omega: Mat,
        x_cal: &Mat,
        replication: usize,
    ) -> Result<()> {
        if self.lanes.contains_key(&lane) {
            return Err(Error::Coordinator(format!(
                "lane {lane:?} already programmed (use reprogram_lane to rewrite it)"
            )));
        }
        self.write_lane(lane, omega, x_cal, replication)
    }

    /// Idempotently (re)program Ω for a lane: frees any existing placement
    /// and runs the full calibrate + GDP flow again. Reprogramming writes
    /// fresh conductances, so the lane's drift clock restarts — this is
    /// the primitive the drift-aware recalibration scheduler
    /// (`fleet::recal`) relies on.
    pub fn reprogram_lane(
        &mut self,
        lane: KernelLane,
        omega: Mat,
        x_cal: &Mat,
        replication: usize,
    ) -> Result<()> {
        let name = lane_matrix_name(lane);
        // validate the rewrite before tearing down the serving placement,
        // so a rejected reprogram leaves the old lane intact
        {
            let chip = self.chip.lock().unwrap();
            if x_cal.cols != omega.rows {
                return Err(Error::Shape(format!(
                    "calibration inputs are {}-d but Ω has {} rows",
                    x_cal.cols, omega.rows
                )));
            }
            let freed = chip.placement_tiles(&name).unwrap_or(0);
            let need = chip.tiles_needed(omega.rows, omega.cols) * replication.max(1);
            if need > chip.cores_free() + freed {
                return Err(Error::Chip(format!(
                    "not enough cores to reprogram lane {lane:?}: need {need}, \
                     free {} after reclaiming the old placement",
                    chip.cores_free() + freed
                )));
            }
        }
        if self.lanes.remove(&lane).is_some() {
            self.chip.lock().unwrap().unprogram(&name);
        }
        self.write_lane(lane, omega, x_cal, replication)
    }

    fn write_lane(
        &mut self,
        lane: KernelLane,
        omega: Mat,
        x_cal: &Mat,
        replication: usize,
    ) -> Result<()> {
        let name = lane_matrix_name(lane);
        let mut chip = self.chip.lock().unwrap();
        let handle = chip.program_matrix(&name, &omega, x_cal, replication)?;
        drop(chip);
        let (d, m) = (omega.rows, omega.cols);
        self.lanes.insert(lane, LaneMapping { handle, omega, d, m });
        Ok(())
    }

    pub fn mapping(&self, lane: KernelLane) -> Result<&LaneMapping> {
        self.lanes
            .get(&lane)
            .ok_or_else(|| Error::Coordinator(format!("lane {lane:?} not programmed")))
    }

    /// Analog projection u = x·Ω on the chip.
    pub fn project(&self, lane: KernelLane, x: &Mat) -> Result<Mat> {
        let mapping = self.mapping(lane)?;
        let mut chip = self.chip.lock().unwrap();
        chip.matmul(&mapping.handle, x)
    }

    pub fn cores_used(&self) -> usize {
        self.chip.lock().unwrap().cores_used()
    }

    pub fn utilization(&self) -> f64 {
        self.chip.lock().unwrap().utilization()
    }

    /// Mean GDP programming error across a lane's tiles.
    pub fn programming_rms(&self, lane: KernelLane) -> Result<f64> {
        let mapping = self.mapping(lane)?;
        let chip = self.chip.lock().unwrap();
        let stats = chip
            .program_stats(&mapping.handle)
            .ok_or_else(|| Error::Coordinator("no stats".into()))?;
        Ok(stats.iter().map(|s| s.rms_final).sum::<f64>() / stats.len().max(1) as f64)
    }
}

/// Chip-level matrix name of a lane's Ω placement.
pub fn lane_matrix_name(lane: KernelLane) -> String {
    format!("omega_{}", lane.kernel().as_str())
}

/// Deterministic Ω generator for serving lanes.
pub fn lane_omega(lane: KernelLane, d: usize, m: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed ^ 0x0_4E6A ^ lane as u64);
    crate::features::sample_omega(crate::features::Sampler::Orf, d, m, &mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::rel_fro_error;

    #[test]
    fn program_and_project() {
        let mut pool = TilePool::new(ChipConfig::default(), 1);
        let mut rng = Rng::new(0);
        let omega = Mat::randn(16, 64, &mut rng);
        let x_cal = Mat::randn(32, 16, &mut rng);
        pool.program_lane(KernelLane::Rbf, omega.clone(), &x_cal, 1)
            .unwrap();
        assert_eq!(pool.cores_used(), 1);
        let x = Mat::randn(8, 16, &mut rng);
        let u = pool.project(KernelLane::Rbf, &x).unwrap();
        let want = crate::linalg::matmul(&x, &omega);
        let rel = rel_fro_error(&u.data, &want.data);
        assert!(rel > 0.0 && rel < 0.12, "rel {rel}");
        assert!(pool.programming_rms(KernelLane::Rbf).unwrap() < 0.05);
    }

    #[test]
    fn double_program_rejected_with_typed_error() {
        let mut pool = TilePool::new(ChipConfig::default(), 2);
        let mut rng = Rng::new(1);
        let omega = Mat::randn(8, 8, &mut rng);
        let x = Mat::randn(8, 8, &mut rng);
        pool.program_lane(KernelLane::Softmax, omega.clone(), &x, 1)
            .unwrap();
        let err = pool
            .program_lane(KernelLane::Softmax, omega, &x, 1)
            .unwrap_err();
        assert!(matches!(err, Error::Coordinator(_)), "{err:?}");
        assert!(err.to_string().contains("already programmed"));
        // the rejected call must not have leaked cores
        assert_eq!(pool.cores_used(), 1);
    }

    #[test]
    fn reprogram_lane_is_idempotent_and_frees_cores() {
        let mut pool = TilePool::new(ChipConfig::default(), 4);
        let mut rng = Rng::new(5);
        let omega = Mat::randn(16, 32, &mut rng);
        let x_cal = Mat::randn(16, 16, &mut rng);
        // works on an unprogrammed lane
        pool.reprogram_lane(KernelLane::Rbf, omega.clone(), &x_cal, 1)
            .unwrap();
        assert_eq!(pool.cores_used(), 1);
        // and on an already-programmed lane, without accumulating cores
        for _ in 0..3 {
            pool.reprogram_lane(KernelLane::Rbf, omega.clone(), &x_cal, 1)
                .unwrap();
            assert_eq!(pool.cores_used(), 1);
        }
        let x = Mat::randn(4, 16, &mut rng);
        let u = pool.project(KernelLane::Rbf, &x).unwrap();
        let want = crate::linalg::matmul(&x, &omega);
        assert!(rel_fro_error(&u.data, &want.data) < 0.12);
        // a different Ω geometry can replace the lane entirely
        let omega2 = Mat::randn(8, 16, &mut rng);
        let x_cal2 = Mat::randn(16, 8, &mut rng);
        pool.reprogram_lane(KernelLane::Rbf, omega2, &x_cal2, 1)
            .unwrap();
        assert_eq!(pool.mapping(KernelLane::Rbf).unwrap().d, 8);
        assert_eq!(pool.cores_used(), 1);
    }

    #[test]
    fn failed_reprogram_keeps_old_lane() {
        let mut cfg = ChipConfig::default();
        cfg.cores = 2;
        cfg.rows = 8;
        cfg.cols = 8;
        let mut pool = TilePool::new(cfg, 6);
        let mut rng = Rng::new(9);
        let omega = Mat::randn(8, 8, &mut rng);
        let x_cal = Mat::randn(8, 8, &mut rng);
        pool.program_lane(KernelLane::Rbf, omega.clone(), &x_cal, 1).unwrap();
        // 8x32 needs 4 tiles; only 2 exist even after reclaiming 1
        let too_wide = Mat::randn(8, 32, &mut rng);
        let err = pool
            .reprogram_lane(KernelLane::Rbf, too_wide, &x_cal, 1)
            .unwrap_err();
        assert!(err.to_string().contains("not enough cores"), "{err:?}");
        // old lane is intact and still serves
        assert_eq!(pool.mapping(KernelLane::Rbf).unwrap().m, 8);
        let x = Mat::randn(2, 8, &mut rng);
        assert!(pool.project(KernelLane::Rbf, &x).is_ok());
    }

    #[test]
    fn unprogrammed_lane_errors() {
        let pool = TilePool::new(ChipConfig::default(), 3);
        let x = Mat::zeros(1, 4);
        assert!(pool.project(KernelLane::ArcCos0, &x).is_err());
    }
}
