//! Tile pool: owns the simulated chip and programs the mapping matrices
//! of each feature lane (with optional replication across spare cores).
//!
//! Single-chip sibling of `fleet::FleetPool`, sharing its lock
//! discipline: the chip sits behind a `RwLock`, analog MVMs take the
//! read lock (projections on disjoint cores run concurrently — the
//! seed's `Mutex<Chip>` serialized every MVM in the process), and only
//! (re)programming takes the write lock. All methods are `&self`, so a
//! shared `TilePool` serves many worker threads directly.

use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};

use super::request::KernelLane;
use crate::aimc::{Chip, MatrixHandle};
use crate::config::ChipConfig;
use crate::error::{Error, Result};
use crate::linalg::Mat;
use crate::util::Rng;

/// One programmed feature-mapping matrix.
pub struct LaneMapping {
    pub handle: MatrixHandle,
    /// the FP-32 Ω (digital-path twin of the programmed weights)
    pub omega: Mat,
    pub d: usize,
    pub m: usize,
}

/// The chip + its programmed lanes.
pub struct TilePool {
    /// read lock for MVMs, write lock for (re)programming
    chip: RwLock<Chip>,
    lanes: RwLock<BTreeMap<KernelLane, Arc<LaneMapping>>>,
}

impl TilePool {
    pub fn new(cfg: ChipConfig, seed: u64) -> TilePool {
        TilePool {
            chip: RwLock::new(Chip::new(cfg, seed)),
            lanes: RwLock::new(BTreeMap::new()),
        }
    }

    /// Program Ω for a feature lane. `x_cal` is a sample of (normalized)
    /// inputs used for DAC/ADC calibration; `replication` spreads copies
    /// over spare cores for throughput.
    ///
    /// Programming the same lane twice is a caller bug and returns a typed
    /// [`Error::Coordinator`] *before* touching the chip (the chip-level
    /// duplicate-name check never fires, so no cores are leaked to a
    /// half-programmed placement). Use [`TilePool::reprogram_lane`] when
    /// rewriting an existing lane is intended (recalibration).
    pub fn program_lane(
        &self,
        lane: KernelLane,
        omega: Mat,
        x_cal: &Mat,
        replication: usize,
    ) -> Result<()> {
        if self.lanes.read().unwrap().contains_key(&lane) {
            return Err(Error::Coordinator(format!(
                "lane {lane:?} already programmed (use reprogram_lane to rewrite it)"
            )));
        }
        self.write_lane(lane, omega, x_cal, replication)
    }

    /// Idempotently (re)program Ω for a lane: frees any existing placement
    /// and runs the full calibrate + GDP flow again. Reprogramming writes
    /// fresh conductances, so the lane's drift clock restarts — this is
    /// the primitive the drift-aware recalibration scheduler
    /// (`fleet::recal`) relies on.
    ///
    /// Atomic with respect to concurrent `project` calls: the old
    /// placement is unprogrammed and the new one written under ONE chip
    /// write-lock hold, and the lanes-map entry is never removed — a
    /// projection therefore runs either entirely before the rewrite (old
    /// conductances) or entirely after it (new conductances, same matrix
    /// name), and never observes a missing lane or a half-written
    /// placement. (If the rewrite changes the lane's geometry, a racing
    /// caller still holding the old shape gets a clean `Shape` error.)
    pub fn reprogram_lane(
        &self,
        lane: KernelLane,
        omega: Mat,
        x_cal: &Mat,
        replication: usize,
    ) -> Result<()> {
        let name = lane_matrix_name(lane);
        if x_cal.cols != omega.rows {
            return Err(Error::Shape(format!(
                "calibration inputs are {}-d but Ω has {} rows",
                x_cal.cols, omega.rows
            )));
        }
        let handle = {
            let mut chip = self.chip.write().unwrap();
            // validate against capacity with the old placement reclaimed
            // *before* tearing it down, so a rejected reprogram leaves
            // the old lane intact and serving
            let freed = chip.placement_tiles(&name).unwrap_or(0);
            let need = chip.tiles_needed(omega.rows, omega.cols) * replication.max(1);
            if need > chip.cores_free() + freed {
                return Err(Error::Chip(format!(
                    "not enough cores to reprogram lane {lane:?}: need {need}, \
                     free {} after reclaiming the old placement",
                    chip.cores_free() + freed
                )));
            }
            chip.unprogram(&name);
            chip.program_matrix(&name, &omega, x_cal, replication)?
        };
        let (d, m) = (omega.rows, omega.cols);
        self.lanes
            .write()
            .unwrap()
            .insert(lane, Arc::new(LaneMapping { handle, omega, d, m }));
        Ok(())
    }

    fn write_lane(
        &self,
        lane: KernelLane,
        omega: Mat,
        x_cal: &Mat,
        replication: usize,
    ) -> Result<()> {
        let name = lane_matrix_name(lane);
        let handle = {
            let mut chip = self.chip.write().unwrap();
            chip.program_matrix(&name, &omega, x_cal, replication)?
        };
        let (d, m) = (omega.rows, omega.cols);
        self.lanes
            .write()
            .unwrap()
            .insert(lane, Arc::new(LaneMapping { handle, omega, d, m }));
        Ok(())
    }

    pub fn mapping(&self, lane: KernelLane) -> Result<Arc<LaneMapping>> {
        self.lanes
            .read()
            .unwrap()
            .get(&lane)
            .cloned()
            .ok_or_else(|| Error::Coordinator(format!("lane {lane:?} not programmed")))
    }

    /// Analog projection u = x·Ω on the chip. Takes only the chip's read
    /// lock: projections of different lanes (disjoint cores) — and
    /// round-robined replicas of one lane — execute concurrently.
    pub fn project(&self, lane: KernelLane, x: &Mat) -> Result<Mat> {
        let mapping = self.mapping(lane)?;
        let chip = self.chip.read().unwrap();
        chip.matmul(&mapping.handle, x)
    }

    pub fn cores_used(&self) -> usize {
        self.chip.read().unwrap().cores_used()
    }

    pub fn utilization(&self) -> f64 {
        self.chip.read().unwrap().utilization()
    }

    /// Mean GDP programming error across a lane's tiles.
    pub fn programming_rms(&self, lane: KernelLane) -> Result<f64> {
        let mapping = self.mapping(lane)?;
        let chip = self.chip.read().unwrap();
        let stats = chip
            .program_stats(&mapping.handle)
            .ok_or_else(|| Error::Coordinator("no stats".into()))?;
        Ok(stats.iter().map(|s| s.rms_final).sum::<f64>() / stats.len().max(1) as f64)
    }
}

/// Chip-level matrix name of a lane's Ω placement.
pub fn lane_matrix_name(lane: KernelLane) -> String {
    format!("omega_{}", lane.kernel().as_str())
}

/// Deterministic Ω generator for serving lanes.
pub fn lane_omega(lane: KernelLane, d: usize, m: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed ^ 0x0_4E6A ^ lane as u64);
    crate::features::sample_omega(crate::features::Sampler::Orf, d, m, &mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::rel_fro_error;

    #[test]
    fn program_and_project() {
        let pool = TilePool::new(ChipConfig::default(), 1);
        let mut rng = Rng::new(0);
        let omega = Mat::randn(16, 64, &mut rng);
        let x_cal = Mat::randn(32, 16, &mut rng);
        pool.program_lane(KernelLane::Rbf, omega.clone(), &x_cal, 1)
            .unwrap();
        assert_eq!(pool.cores_used(), 1);
        let x = Mat::randn(8, 16, &mut rng);
        let u = pool.project(KernelLane::Rbf, &x).unwrap();
        let want = crate::linalg::matmul(&x, &omega);
        let rel = rel_fro_error(&u.data, &want.data);
        assert!(rel > 0.0 && rel < 0.12, "rel {rel}");
        assert!(pool.programming_rms(KernelLane::Rbf).unwrap() < 0.05);
    }

    #[test]
    fn double_program_rejected_with_typed_error() {
        let pool = TilePool::new(ChipConfig::default(), 2);
        let mut rng = Rng::new(1);
        let omega = Mat::randn(8, 8, &mut rng);
        let x = Mat::randn(8, 8, &mut rng);
        pool.program_lane(KernelLane::Softmax, omega.clone(), &x, 1)
            .unwrap();
        let err = pool
            .program_lane(KernelLane::Softmax, omega, &x, 1)
            .unwrap_err();
        assert!(matches!(err, Error::Coordinator(_)), "{err:?}");
        assert!(err.to_string().contains("already programmed"));
        // the rejected call must not have leaked cores
        assert_eq!(pool.cores_used(), 1);
    }

    #[test]
    fn reprogram_lane_is_idempotent_and_frees_cores() {
        let pool = TilePool::new(ChipConfig::default(), 4);
        let mut rng = Rng::new(5);
        let omega = Mat::randn(16, 32, &mut rng);
        let x_cal = Mat::randn(16, 16, &mut rng);
        // works on an unprogrammed lane
        pool.reprogram_lane(KernelLane::Rbf, omega.clone(), &x_cal, 1)
            .unwrap();
        assert_eq!(pool.cores_used(), 1);
        // and on an already-programmed lane, without accumulating cores
        for _ in 0..3 {
            pool.reprogram_lane(KernelLane::Rbf, omega.clone(), &x_cal, 1)
                .unwrap();
            assert_eq!(pool.cores_used(), 1);
        }
        let x = Mat::randn(4, 16, &mut rng);
        let u = pool.project(KernelLane::Rbf, &x).unwrap();
        let want = crate::linalg::matmul(&x, &omega);
        assert!(rel_fro_error(&u.data, &want.data) < 0.12);
        // a different Ω geometry can replace the lane entirely
        let omega2 = Mat::randn(8, 16, &mut rng);
        let x_cal2 = Mat::randn(16, 8, &mut rng);
        pool.reprogram_lane(KernelLane::Rbf, omega2, &x_cal2, 1)
            .unwrap();
        assert_eq!(pool.mapping(KernelLane::Rbf).unwrap().d, 8);
        assert_eq!(pool.cores_used(), 1);
    }

    #[test]
    fn failed_reprogram_keeps_old_lane() {
        let mut cfg = ChipConfig::default();
        cfg.cores = 2;
        cfg.rows = 8;
        cfg.cols = 8;
        let pool = TilePool::new(cfg, 6);
        let mut rng = Rng::new(9);
        let omega = Mat::randn(8, 8, &mut rng);
        let x_cal = Mat::randn(8, 8, &mut rng);
        pool.program_lane(KernelLane::Rbf, omega.clone(), &x_cal, 1).unwrap();
        // 8x32 needs 4 tiles; only 2 exist even after reclaiming 1
        let too_wide = Mat::randn(8, 32, &mut rng);
        let err = pool
            .reprogram_lane(KernelLane::Rbf, too_wide, &x_cal, 1)
            .unwrap_err();
        assert!(err.to_string().contains("not enough cores"), "{err:?}");
        // old lane is intact and still serves
        assert_eq!(pool.mapping(KernelLane::Rbf).unwrap().m, 8);
        let x = Mat::randn(2, 8, &mut rng);
        assert!(pool.project(KernelLane::Rbf, &x).is_ok());
    }

    #[test]
    fn concurrent_projections_share_the_chip() {
        // two lanes on disjoint cores of one chip, projected from four
        // threads through &TilePool — the single-chip core-parallel path
        let pool = TilePool::new(ChipConfig::default(), 7);
        let mut rng = Rng::new(11);
        let om_a = Mat::randn(16, 32, &mut rng);
        let om_b = Mat::randn(16, 32, &mut rng);
        let x_cal = Mat::randn(32, 16, &mut rng);
        pool.program_lane(KernelLane::Rbf, om_a.clone(), &x_cal, 1).unwrap();
        pool.program_lane(KernelLane::Softmax, om_b.clone(), &x_cal, 1).unwrap();
        let x = Mat::randn(8, 16, &mut rng);
        let wants = [
            crate::linalg::matmul(&x, &om_a),
            crate::linalg::matmul(&x, &om_b),
        ];
        let lanes = [KernelLane::Rbf, KernelLane::Softmax];
        let pool_ref = &pool;
        let x_ref = &x;
        let wants_ref = &wants;
        let errs = crate::util::threads::parallel_map(4, |i| {
            let u = pool_ref.project(lanes[i % 2], x_ref).unwrap();
            rel_fro_error(&u.data, &wants_ref[i % 2].data)
        });
        assert!(errs.iter().all(|&e| e > 0.0 && e < 0.12), "{errs:?}");
    }

    #[test]
    fn unprogrammed_lane_errors() {
        let pool = TilePool::new(ChipConfig::default(), 3);
        let x = Mat::zeros(1, 4);
        assert!(pool.project(KernelLane::ArcCos0, &x).is_err());
    }
}
