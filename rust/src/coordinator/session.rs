//! Attention-session registry: per-session FAVOR+ running state plus the
//! fleet-wired φ(q)/φ(k) projection paths.
//!
//! Sessions hold O(1) state per head ([`crate::attention::serve::HeadState`]);
//! the per-head Ω matrices are shared across every session and, on the
//! analog path, programmed onto the fleet as [`LaneId::AttnHead`] lanes —
//! so they shard, replicate, recalibrate and fail over exactly like the
//! feature lanes. Session state lives here, off-chip, which is what lets
//! an open session keep streaming through a chip eviction: only the φ
//! projection moves to surviving replicas.
//!
//! Projection paths mirror the feature workload:
//! - `Digital` (fp32): φ via [`positive_features`] against the digital
//!   twin Ω — native Rust, no XLA artifact needed.
//! - `Analog`: u = x·Ω on the fleet ([`FleetPool::project`]), then the
//!   native softmax postprocess (exactly the split the paper's Fig. 3b
//!   protocol isolates).
//!
//! Append ingest borrows: `append_to` takes the q/k/v token rows as
//! `&[f32]` slices into the buffers the wire codec decoded (which the
//! batched requests still own), so streaming a token from socket to
//! per-head state costs one decode, zero re-copies.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use super::request::{LaneId, PathKind};
use crate::attention::serve::HeadState;
use crate::config::AttnServeConfig;
use crate::error::{Error, Result};
use crate::features::favor::positive_features;
use crate::features::maps::postprocess;
use crate::features::{sample_omega, Sampler};
use crate::fleet::FleetPool;
use crate::kernels::Kernel;
use crate::linalg::Mat;
use crate::obsv::MvmProfile;
use crate::util::Rng;

/// Deterministic per-head Ω: the digital twin of the programmed analog
/// lane and the matrix the fp32 path projects against, so both paths of
/// one deployment share identical random features.
pub fn head_omega(cfg: &AttnServeConfig, head: usize) -> Mat {
    let mut rng = Rng::new(cfg.seed ^ (head as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    sample_omega(Sampler::Orf, cfg.d_head, cfg.m, &mut rng)
}

/// Immutable descriptor returned by `attn_open`.
#[derive(Clone, Copy, Debug)]
pub struct AttnSessionInfo {
    pub id: u64,
    pub path: PathKind,
    pub heads: usize,
    pub d_head: usize,
    pub m: usize,
}

struct SessionInner {
    heads: Vec<HeadState>,
}

/// One open streaming-attention session.
pub struct Session {
    pub id: u64,
    pub path: PathKind,
    inner: Mutex<SessionInner>,
}

impl Session {
    /// Tokens streamed into this session so far.
    pub fn tokens(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner.heads.first().map(|h| h.tokens()).unwrap_or(0)
    }
}

/// Aggregate session counters for the `stats` surface.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SessionStatsSnapshot {
    /// sessions currently open
    pub active: usize,
    /// sessions opened since boot
    pub opened: u64,
    /// sessions closed since boot
    pub closed: u64,
    /// tokens streamed across all sessions since boot
    pub tokens: u64,
}

/// Registry of open sessions + the shared per-head Ω twins.
pub struct SessionManager {
    cfg: AttnServeConfig,
    /// within-chip copy count for the analog head lanes (mirrors the
    /// feature lanes' `serve.replication`)
    core_replication: usize,
    omegas: Vec<Mat>,
    /// serializes first-open lane programming (two concurrent opens must
    /// not race `program_lane` — the loser would see a transient
    /// "already placed" error while the winner is still mid-GDP)
    lane_init: Mutex<()>,
    sessions: RwLock<BTreeMap<u64, Arc<Session>>>,
    next_id: AtomicU64,
    opened: AtomicU64,
    closed: AtomicU64,
    tokens: AtomicU64,
}

impl SessionManager {
    pub fn new(cfg: AttnServeConfig, core_replication: usize) -> SessionManager {
        let omegas = (0..cfg.heads).map(|h| head_omega(&cfg, h)).collect();
        SessionManager {
            cfg,
            core_replication: core_replication.max(1),
            omegas,
            lane_init: Mutex::new(()),
            sessions: RwLock::new(BTreeMap::new()),
            next_id: AtomicU64::new(1),
            opened: AtomicU64::new(0),
            closed: AtomicU64::new(0),
            tokens: AtomicU64::new(0),
        }
    }

    pub fn config(&self) -> &AttnServeConfig {
        &self.cfg
    }

    /// The configured default projection path for `attn_open`.
    pub fn default_path(&self) -> PathKind {
        PathKind::parse(&self.cfg.path).unwrap_or(PathKind::Analog)
    }

    /// Program the per-head Ω lanes onto the fleet if absent (first
    /// analog open, lazily — digital-only deployments never pay for it).
    /// `lane_init` serializes concurrent first opens, so the absent-check
    /// and the programming are atomic with respect to other opens.
    fn ensure_lanes(&self, pool: &FleetPool) -> Result<()> {
        let _guard = self.lane_init.lock().unwrap();
        for h in 0..self.cfg.heads {
            let lane = LaneId::AttnHead(h as u32);
            if pool.mapping(lane).is_ok() {
                continue;
            }
            // calibration inputs match serving statistics: scaled queries
            // x·d^-1/4 of roughly unit-normal heads
            let mut rng = Rng::new(self.cfg.seed ^ (0xCA1B ^ h as u64));
            let mut x_cal = Mat::randn(64, self.cfg.d_head, &mut rng);
            x_cal.scale((self.cfg.d_head as f32).powf(-0.25));
            pool.program_lane(lane, self.omegas[h].clone(), &x_cal, self.core_replication)?;
        }
        Ok(())
    }

    /// Open a session on `path` (falling back to the configured default).
    pub fn open(&self, pool: &FleetPool, path: Option<PathKind>) -> Result<AttnSessionInfo> {
        let path = path.unwrap_or_else(|| self.default_path());
        if path == PathKind::Analog {
            // idempotent, so doing it before the registry lock is safe;
            // a concurrent open that loses the limit check below leaves
            // the lanes programmed for the winner
            self.ensure_lanes(pool)?;
        }
        // limit check and insert under one write lock, so concurrent
        // opens cannot overshoot max_sessions
        let mut sessions = self.sessions.write().unwrap();
        if sessions.len() >= self.cfg.max_sessions {
            return Err(Error::Coordinator(format!(
                "session limit reached ({} open, max_sessions {})",
                sessions.len(),
                self.cfg.max_sessions
            )));
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let heads = (0..self.cfg.heads)
            .map(|_| HeadState::new(2 * self.cfg.m, self.cfg.d_head))
            .collect();
        sessions.insert(id, Arc::new(Session { id, path, inner: Mutex::new(SessionInner { heads }) }));
        self.opened.fetch_add(1, Ordering::Relaxed);
        Ok(AttnSessionInfo {
            id,
            path,
            heads: self.cfg.heads,
            d_head: self.cfg.d_head,
            m: self.cfg.m,
        })
    }

    pub fn get(&self, id: u64) -> Result<Arc<Session>> {
        self.sessions
            .read()
            .unwrap()
            .get(&id)
            .cloned()
            .ok_or_else(|| Error::Coordinator(format!("no open attention session {id}")))
    }

    /// Close a session; returns the number of tokens it streamed.
    pub fn close(&self, id: u64) -> Result<usize> {
        let session = self
            .sessions
            .write()
            .unwrap()
            .remove(&id)
            .ok_or_else(|| Error::Coordinator(format!("no open attention session {id}")))?;
        self.closed.fetch_add(1, Ordering::Relaxed);
        Ok(session.tokens())
    }

    /// φ for a block of scaled inputs on the session's path. `xs` rows
    /// are already scaled by d_head^-1/4.
    fn phi(
        &self,
        pool: &FleetPool,
        path: PathKind,
        head: usize,
        xs: &Mat,
        profile: Option<&MvmProfile>,
    ) -> Result<Mat> {
        match path {
            PathKind::Digital => Ok(positive_features(xs, &self.omegas[head])),
            PathKind::Analog => {
                let u = pool.project_with(LaneId::AttnHead(head as u32), xs, profile)?;
                Ok(postprocess(Kernel::Softmax, &u, Some(xs)))
            }
        }
    }

    /// Stream a batch of tokens into the session with this id, in order
    /// (convenience wrapper over [`SessionManager::append_to`]).
    pub fn append_batch(
        &self,
        pool: &FleetPool,
        id: u64,
        items: &[(&[f32], &[f32], &[f32])],
    ) -> Result<Vec<(Vec<f32>, usize)>> {
        let session = self.get(id)?;
        self.append_to(pool, &session, items, None)
    }

    /// Stream a batch of tokens into one session, in order. Each item is
    /// the flattened (q, k, v) of one token (heads × d_head each);
    /// returns the attention output and 0-based token index per item.
    ///
    /// The φ projections of the whole batch are computed per head in one
    /// fleet call (q rows then k rows), so a batch of appends pays
    /// 2 × heads projection round-trips instead of 2 × heads × tokens —
    /// the batching payoff the lane-affinity batcher exists to harvest.
    ///
    /// `profile`, when given, accumulates the analog path's lock-wait
    /// and on-chip matmul time across the per-head projections (for
    /// trace spans and the bench's per-stage means).
    pub fn append_to(
        &self,
        pool: &FleetPool,
        session: &Session,
        items: &[(&[f32], &[f32], &[f32])],
        profile: Option<&MvmProfile>,
    ) -> Result<Vec<(Vec<f32>, usize)>> {
        self.append_to_on(pool, session, items, profile, session.path)
    }

    /// [`SessionManager::append_to`] with the φ substrate chosen by the
    /// caller instead of the session's opened path. Both substrates
    /// project against the same Ω twins, so the engine's dispatch layer
    /// can run an analog session's batch digitally (small batch, drifted
    /// fleet) without perturbing the running FAVOR+ state: only *where*
    /// φ executes changes, never its distribution.
    pub fn append_to_on(
        &self,
        pool: &FleetPool,
        session: &Session,
        items: &[(&[f32], &[f32], &[f32])],
        profile: Option<&MvmProfile>,
        exec_path: PathKind,
    ) -> Result<Vec<(Vec<f32>, usize)>> {
        let (heads, d_head) = (self.cfg.heads, self.cfg.d_head);
        let dim = heads * d_head;
        for (q, k, v) in items {
            if q.len() != dim || k.len() != dim || v.len() != dim {
                return Err(Error::Shape(format!(
                    "attn_append expects q/k/v of {dim} values ({heads} heads x {d_head}), \
                     got {}/{}/{}",
                    q.len(),
                    k.len(),
                    v.len()
                )));
            }
        }
        let n = items.len();
        let scale = (d_head as f32).powf(-0.25);
        // per head: one (2n x d_head) block — scaled q rows, then k rows
        let mut phis = Vec::with_capacity(heads);
        for h in 0..heads {
            let mut xs = Mat::zeros(2 * n, d_head);
            for (t, (q, k, _)) in items.iter().enumerate() {
                let qd = xs.row_mut(t);
                for (dst, &src) in qd.iter_mut().zip(&q[h * d_head..(h + 1) * d_head]) {
                    *dst = src * scale;
                }
                let kd = xs.row_mut(n + t);
                for (dst, &src) in kd.iter_mut().zip(&k[h * d_head..(h + 1) * d_head]) {
                    *dst = src * scale;
                }
            }
            phis.push(self.phi(pool, exec_path, h, &xs, profile)?);
        }
        // fold tokens into the running state in arrival order, answering
        // each with its post-absorb attention output
        let mut inner = session.inner.lock().unwrap();
        let mut out = Vec::with_capacity(n);
        for (t, (_, _, v)) in items.iter().enumerate() {
            let mut y = vec![0.0f32; dim];
            let mut index = 0;
            for h in 0..heads {
                let state = &mut inner.heads[h];
                state.absorb(phis[h].row(n + t), &v[h * d_head..(h + 1) * d_head]);
                index = state.tokens() - 1;
                y[h * d_head..(h + 1) * d_head].copy_from_slice(&state.attend(phis[h].row(t)));
            }
            out.push((y, index));
        }
        self.tokens.fetch_add(n as u64, Ordering::Relaxed);
        Ok(out)
    }

    pub fn snapshot(&self) -> SessionStatsSnapshot {
        SessionStatsSnapshot {
            active: self.sessions.read().unwrap().len(),
            opened: self.opened.load(Ordering::Relaxed),
            closed: self.closed.load(Ordering::Relaxed),
            tokens: self.tokens.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChipConfig, FleetConfig};

    fn cfg() -> AttnServeConfig {
        AttnServeConfig {
            heads: 2,
            d_head: 8,
            m: 16,
            max_sessions: 2,
            path: "fp32".to_string(),
            seed: 7,
        }
    }

    fn pool() -> FleetPool {
        FleetPool::new(
            ChipConfig { cores: 8, rows: 16, cols: 16, ..ChipConfig::default() },
            FleetConfig::default(),
            1,
        )
    }

    #[test]
    fn open_append_close_roundtrip() {
        let mgr = SessionManager::new(cfg(), 1);
        let pool = pool();
        let info = mgr.open(&pool, None).unwrap();
        assert_eq!(info.path, PathKind::Digital); // cfg default "fp32"
        let dim = info.heads * info.d_head;
        let q = vec![0.1f32; dim];
        let k = vec![0.2f32; dim];
        let v = vec![0.3f32; dim];
        let out = mgr
            .append_batch(&pool, info.id, &[(&q, &k, &v), (&q, &k, &v)])
            .unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].1, 0);
        assert_eq!(out[1].1, 1);
        assert_eq!(out[0].0.len(), dim);
        assert!(out[1].0.iter().all(|y| y.is_finite()));
        let snap = mgr.snapshot();
        assert_eq!((snap.active, snap.opened, snap.tokens), (1, 1, 2));
        assert_eq!(mgr.close(info.id).unwrap(), 2);
        assert_eq!(mgr.snapshot().active, 0);
        // closed sessions are gone
        assert!(mgr.close(info.id).is_err());
        assert!(mgr.append_batch(&pool, info.id, &[(&q, &k, &v)]).is_err());
    }

    #[test]
    fn session_limit_is_enforced() {
        let mgr = SessionManager::new(cfg(), 1);
        let pool = pool();
        let a = mgr.open(&pool, Some(PathKind::Digital)).unwrap();
        let _b = mgr.open(&pool, Some(PathKind::Digital)).unwrap();
        assert!(mgr.open(&pool, Some(PathKind::Digital)).is_err());
        mgr.close(a.id).unwrap();
        mgr.open(&pool, Some(PathKind::Digital)).unwrap();
    }

    #[test]
    fn bad_append_shape_is_typed_error() {
        let mgr = SessionManager::new(cfg(), 1);
        let pool = pool();
        let info = mgr.open(&pool, Some(PathKind::Digital)).unwrap();
        let short = vec![0.0f32; 3];
        let ok = vec![0.0f32; info.heads * info.d_head];
        let err = mgr
            .append_batch(&pool, info.id, &[(&short, &ok, &ok)])
            .unwrap_err();
        assert!(matches!(err, Error::Shape(_)), "{err:?}");
    }

    #[test]
    fn append_to_on_overrides_the_phi_substrate() {
        let mgr = SessionManager::new(cfg(), 1);
        let pool = pool();
        let info = mgr.open(&pool, Some(PathKind::Analog)).unwrap();
        let session = mgr.get(info.id).unwrap();
        let dim = info.heads * info.d_head;
        let q = vec![0.1f32; dim];
        let k = vec![0.2f32; dim];
        let v = vec![0.3f32; dim];
        // an analog session's batch can run digitally: same Ω twins, so
        // the running state stays coherent across the substrate switch
        let out = mgr
            .append_to_on(&pool, &session, &[(&q, &k, &v)], None, PathKind::Digital)
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].1, 0);
        assert!(out[0].0.iter().all(|y| y.is_finite()));
        // and the next batch can go back to the chip
        let out = mgr
            .append_to_on(&pool, &session, &[(&q, &k, &v)], None, PathKind::Analog)
            .unwrap();
        assert_eq!(out[0].1, 1);
        assert!(out[0].0.iter().all(|y| y.is_finite()));
    }

    #[test]
    fn analog_open_programs_head_lanes_once() {
        let mgr = SessionManager::new(cfg(), 1);
        let pool = pool();
        assert!(pool.mapping(LaneId::AttnHead(0)).is_err());
        let a = mgr.open(&pool, Some(PathKind::Analog)).unwrap();
        assert!(pool.mapping(LaneId::AttnHead(0)).is_ok());
        assert!(pool.mapping(LaneId::AttnHead(1)).is_ok());
        let cores = pool.cores_used();
        // second analog open reuses the programmed lanes
        mgr.close(a.id).unwrap();
        mgr.open(&pool, Some(PathKind::Analog)).unwrap();
        assert_eq!(pool.cores_used(), cores);
    }
}
