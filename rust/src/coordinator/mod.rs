//! L3 serving coordinator — the system contribution: a workload-generic
//! inference server that routes kernel-approximation workloads between a
//! fleet of simulated AIMC chips (analog path) and AOT-compiled XLA
//! artifacts (digital path), with dynamic batching, sharded lane
//! placement, replica routing, drift-aware recalibration, telemetry, and
//! a TCP line protocol.
//!
//! Three workloads share one pipeline ([`request::WorkloadKind`]):
//! stateless kernel **features**, whole-sequence **performer**
//! classification, and streaming kernelized-**attention** sessions
//! ([`session`]) whose per-head Ω lanes live on the fleet next to the
//! feature lanes while the O(1) FAVOR+ running state stays here.
//!
//! Data flow:
//!
//! ```text
//! clients -> Submitter -> ingress queue -> batcher (per-lane, max_batch /
//!   max_wait; attention lanes keyed by session for affinity)
//!          -> dispatcher (feature/performer batches -> worker pool;
//!             attention batches -> session-sharded executors, so one
//!             session's batches apply in emission order while distinct
//!             sessions run concurrently)
//!          -> { FleetPool: router picks a replica per
//!                                 Ω shard -> per-chip MVM queues -> concat
//!                                 + postproc artifact        (analog)
//!                               | fused digital artifact     (digital)
//!                               | performer artifact (+ noisy weights)
//!                               | session state: S += φ(k)vᵀ, z += φ(k);
//!                                 y = φ(q)ᵀS / φ(q)ᵀz      (attention) }
//!          -> replies (+ latency/energy telemetry)
//!
//! background: recal thread -> fleet clock -> drift estimate per chip
//!          -> reprogram chips past the drift budget (one at a time)
//! stats   : TCP `{"type":"stats"}` -> per-lane latency percentiles +
//!           per-chip utilization / queue depth / recal counters
//! ```
//!
//! The single-chip [`TilePool`] remains as the minimal embedding of the
//! chip (examples, experiments); the serving engine itself runs on
//! [`crate::fleet::FleetPool`].

pub mod batcher;
pub mod engine;
pub mod request;
pub mod server;
pub mod session;
pub mod telemetry;
pub mod tilepool;

pub use engine::{Engine, SessionsHandle, StatsHandle, Submitter};
pub use request::{
    LaneId, PathKind, PerfMode, Request, RequestBody, Response, ResponseBody, WorkloadKind,
};
pub use server::{Client, Server};
pub use session::{AttnSessionInfo, SessionManager, SessionStatsSnapshot};
pub use telemetry::{
    render_metrics, ChipSnapshot, FleetEventsSnapshot, LaneSnapshot, LiveGauges, Telemetry,
};
pub use tilepool::TilePool;
