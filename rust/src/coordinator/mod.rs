//! L3 serving coordinator — the system contribution: an inference server
//! that routes kernel-approximation workloads between the simulated AIMC
//! chip (analog path) and AOT-compiled XLA artifacts (digital path), with
//! dynamic batching, a tile pool, telemetry, and a TCP line protocol.
//!
//! Data flow:
//!
//! ```text
//! clients -> Submitter -> ingress queue -> batcher (per-lane, max_batch /
//!   max_wait) -> worker pool -> { TilePool (chip MVM) + postproc artifact
//!                               | fused digital artifact
//!                               | performer artifact (+ noisy weights) }
//!          -> replies (+ latency/energy telemetry)
//! ```

pub mod batcher;
pub mod engine;
pub mod request;
pub mod server;
pub mod telemetry;
pub mod tilepool;

pub use engine::{Engine, Submitter};
pub use request::{PathKind, PerfMode, Request, RequestBody, Response, ResponseBody};
pub use server::{Client, Server};
pub use telemetry::Telemetry;
pub use tilepool::TilePool;
