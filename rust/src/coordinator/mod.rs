//! L3 serving coordinator — the system contribution: an inference server
//! that routes kernel-approximation workloads between a fleet of
//! simulated AIMC chips (analog path) and AOT-compiled XLA artifacts
//! (digital path), with dynamic batching, sharded lane placement, replica
//! routing, drift-aware recalibration, telemetry, and a TCP line
//! protocol.
//!
//! Data flow:
//!
//! ```text
//! clients -> Submitter -> ingress queue -> batcher (per-lane, max_batch /
//!   max_wait) -> worker pool -> { FleetPool: router picks a replica per
//!                                 Ω shard -> per-chip MVM queues -> concat
//!                                 + postproc artifact        (analog)
//!                               | fused digital artifact     (digital)
//!                               | performer artifact (+ noisy weights) }
//!          -> replies (+ latency/energy telemetry)
//!
//! background: recal thread -> fleet clock -> drift estimate per chip
//!          -> reprogram chips past the drift budget (one at a time)
//! stats   : TCP `{"type":"stats"}` -> per-lane latency percentiles +
//!           per-chip utilization / queue depth / recal counters
//! ```
//!
//! The single-chip [`TilePool`] remains as the minimal embedding of the
//! chip (examples, experiments); the serving engine itself runs on
//! [`crate::fleet::FleetPool`].

pub mod batcher;
pub mod engine;
pub mod request;
pub mod server;
pub mod telemetry;
pub mod tilepool;

pub use engine::{Engine, StatsHandle, Submitter};
pub use request::{PathKind, PerfMode, Request, RequestBody, Response, ResponseBody};
pub use server::{Client, Server};
pub use telemetry::{ChipSnapshot, FleetEventsSnapshot, LaneSnapshot, Telemetry};
pub use tilepool::TilePool;
