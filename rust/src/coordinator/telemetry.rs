//! Serving telemetry: per-lane latency percentiles, batch-size stats,
//! modelled energy totals.

use std::collections::BTreeMap;
use std::sync::Mutex;

use super::request::Lane;
use crate::util::Summary;

#[derive(Default)]
struct LaneStats {
    latency_us: Summary,
    batch_sizes: Summary,
    requests: u64,
    errors: u64,
    energy_uj: f64,
}

/// Thread-safe telemetry sink.
#[derive(Default)]
pub struct Telemetry {
    inner: Mutex<BTreeMap<Lane, LaneStats>>,
}

/// Per-chip fleet counters surfaced in the server's `stats` response
/// (produced by `fleet::FleetPool::chip_snapshots`).
#[derive(Clone, Debug)]
pub struct ChipSnapshot {
    /// fleet chip index
    pub chip: usize,
    /// health state label (`healthy`/`degraded`/`draining`/`joining`/
    /// `evicted`, from the control plane's state machine)
    pub health: &'static str,
    /// crossbar cores programmed on this chip
    pub cores_used: usize,
    /// cores_used / this chip's capacity, in [0,1]
    pub utilization: f64,
    /// analog MVMs queued on or executing against this chip right now
    pub queue_depth: usize,
    /// cores currently executing an MVM (summed tile footprint of the
    /// executing shards; read from the slot's atomic gauge — no chip
    /// lock taken). MVMs queued behind a recal write lock are counted
    /// in `queue_depth`, not here. Concurrent reads round-robined onto
    /// the same replica each count their own footprint (back-to-back
    /// reads of the same physical cores), so the sum can transiently
    /// exceed the chip's core count under heavy same-replica load.
    pub busy_cores: usize,
    /// busy_cores / this chip's capacity — live core utilization of the
    /// core-parallel MVM path ([0,1] except under the same-replica
    /// overlap noted on `busy_cores`)
    pub core_utilization: f64,
    /// analog MVMs completed by this chip
    pub served: u64,
    /// failed MVMs/heartbeat probes on this chip since boot
    pub errors: u64,
    /// recalibrations (full reprogram cycles) this chip has undergone
    pub recals: u64,
    /// seconds of fleet-clock time since the last (re)programming
    pub age_s: f64,
    /// analytic drift-error estimate at the current age
    pub drift_err_estimate: f64,
}

/// Control-plane event counters surfaced by the server's `health` verb
/// (produced by `fleet::FleetPool::events`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FleetEventsSnapshot {
    /// chips evicted (health monitor or explicit)
    pub evictions: u64,
    /// chips added + populated by the autoscaler
    pub scale_ups: u64,
    /// chips drained + retired by the autoscaler
    pub scale_downs: u64,
    /// manual drain requests honored
    pub drains: u64,
}

/// Snapshot for one lane.
#[derive(Clone, Debug)]
pub struct LaneSnapshot {
    pub lane: Lane,
    pub requests: u64,
    pub errors: u64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub mean_batch: f64,
    pub energy_uj: f64,
}

impl Telemetry {
    pub fn record(&self, lane: Lane, latency_us: f64, batch: usize, energy_uj: f64, err: bool) {
        let mut inner = self.inner.lock().unwrap();
        let s = inner.entry(lane).or_default();
        s.latency_us.push(latency_us);
        s.batch_sizes.push(batch as f64);
        s.requests += 1;
        if err {
            s.errors += 1;
        }
        s.energy_uj += energy_uj;
    }

    pub fn snapshot(&self) -> Vec<LaneSnapshot> {
        let inner = self.inner.lock().unwrap();
        inner
            .iter()
            .map(|(lane, s)| LaneSnapshot {
                lane: *lane,
                requests: s.requests,
                errors: s.errors,
                p50_us: s.latency_us.p50(),
                p95_us: s.latency_us.p95(),
                p99_us: s.latency_us.p99(),
                mean_batch: s.batch_sizes.mean(),
                energy_uj: s.energy_uj,
            })
            .collect()
    }

    pub fn total_requests(&self) -> u64 {
        self.inner.lock().unwrap().values().map(|s| s.requests).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{KernelLane, PathLane};

    #[test]
    fn records_and_snapshots() {
        let t = Telemetry::default();
        let lane = Lane::Feature(KernelLane::Rbf, PathLane::Analog);
        for i in 0..10 {
            t.record(lane, 100.0 + i as f64, 4, 0.5, i == 9);
        }
        let snap = t.snapshot();
        assert_eq!(snap.len(), 1);
        let s = &snap[0];
        assert_eq!(s.requests, 10);
        assert_eq!(s.errors, 1);
        assert!((s.mean_batch - 4.0).abs() < 1e-9);
        assert!(s.p50_us >= 100.0 && s.p99_us <= 109.0 + 1e-9);
        assert!((s.energy_uj - 5.0).abs() < 1e-9);
        assert_eq!(t.total_requests(), 10);
    }
}
