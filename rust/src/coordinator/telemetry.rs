//! Serving telemetry: bounded, lock-free per-lane metrics plus the
//! Prometheus-style exposition the `metrics` TCP verb serves.
//!
//! PR 2–6 kept one `Mutex<BTreeMap<Lane, Summary>>` here: every
//! `record()` serialized all lanes on one lock and pushed into
//! unbounded `Vec<f64>`s — a contention point and a slow memory leak on
//! a long-running server. The rework stores everything in an
//! [`obsv::MetricsRegistry`]:
//!
//! - per-lane counters (`imka_requests_total`, `imka_request_errors_total`,
//!   `imka_lane_energy_uj_total`) and log-bucketed histograms
//!   (`imka_lane_latency_us`, `imka_lane_batch_size`) — fixed memory per
//!   lane, and the lane set is closed (attention sessions collapse onto
//!   one row via [`Lane::telemetry_key`]);
//! - per-stage histograms `imka_stage_us{stage=...}` for the request
//!   breakdown (parse, queue, dispatch, lock_wait, analog_mvm,
//!   digital_combine, serialize).
//!
//! The hot path (`record`) takes a shared read lock only to fetch the
//! lane's `Arc` of handles (a write lock happens once per lane, on its
//! first request) and then records through relaxed atomics — concurrent
//! lanes, and concurrent requests of one lane, never serialize.
//! The exact, unbounded [`crate::util::stats::Summary`] remains the
//! right tool for offline experiments and benches with finite samples.

use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};

use super::request::Lane;
use super::session::SessionStatsSnapshot;
use crate::obsv::registry::{push_sample, Counter, MetricsRegistry};
use crate::obsv::LogHistogram;

/// Per-lane metric handles, resolved once per lane then shared.
struct LaneCells {
    requests: Arc<Counter>,
    errors: Arc<Counter>,
    energy_uj: Arc<Counter>,
    latency_us: Arc<LogHistogram>,
    batch: Arc<LogHistogram>,
}

/// Per-stage latency histograms (shared across lanes; the stage label
/// is the dimension).
struct StageCells {
    parse: Arc<LogHistogram>,
    queue: Arc<LogHistogram>,
    dispatch: Arc<LogHistogram>,
    lock_wait: Arc<LogHistogram>,
    analog_mvm: Arc<LogHistogram>,
    digital_combine: Arc<LogHistogram>,
    serialize: Arc<LogHistogram>,
}

/// Thread-safe telemetry sink; see module docs.
pub struct Telemetry {
    registry: Arc<MetricsRegistry>,
    lanes: RwLock<BTreeMap<Lane, Arc<LaneCells>>>,
    stages: StageCells,
}

impl Default for Telemetry {
    fn default() -> Self {
        Telemetry::new()
    }
}

/// Per-chip fleet counters surfaced in the server's `stats` response
/// (produced by `fleet::FleetPool::chip_snapshots`).
#[derive(Clone, Debug)]
pub struct ChipSnapshot {
    /// fleet chip index
    pub chip: usize,
    /// health state label (`healthy`/`degraded`/`draining`/`joining`/
    /// `evicted`, from the control plane's state machine)
    pub health: &'static str,
    /// crossbar cores programmed on this chip
    pub cores_used: usize,
    /// cores_used / this chip's capacity, in [0,1]
    pub utilization: f64,
    /// analog MVMs queued on or executing against this chip right now
    pub queue_depth: usize,
    /// cores currently executing an MVM (summed tile footprint of the
    /// executing shards; read from the slot's atomic gauge — no chip
    /// lock taken). MVMs queued behind a recal write lock are counted
    /// in `queue_depth`, not here. Concurrent reads round-robined onto
    /// the same replica each count their own footprint (back-to-back
    /// reads of the same physical cores), so the sum can transiently
    /// exceed the chip's core count under heavy same-replica load.
    pub busy_cores: usize,
    /// busy_cores / this chip's capacity, clamped to [0,1]; the
    /// same-replica overlap beyond capacity is reported separately in
    /// `core_oversubscription` instead of as a >100% utilization
    pub core_utilization: f64,
    /// overlap beyond capacity: max(busy_cores / capacity - 1, 0) — a
    /// nonzero value means concurrent MVMs were round-robined onto the
    /// same replica and are queueing on its physical cores
    pub core_oversubscription: f64,
    /// analog MVMs completed by this chip
    pub served: u64,
    /// failed MVMs/heartbeat probes on this chip since boot
    pub errors: u64,
    /// recalibrations (full reprogram cycles) this chip has undergone
    pub recals: u64,
    /// seconds of fleet-clock time since the last (re)programming
    pub age_s: f64,
    /// analytic drift-error estimate at the current age
    pub drift_err_estimate: f64,
}

/// Control-plane event counters surfaced by the server's `health` verb
/// (produced by `fleet::FleetPool::events`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FleetEventsSnapshot {
    /// chips evicted (health monitor or explicit)
    pub evictions: u64,
    /// chips added + populated by the autoscaler
    pub scale_ups: u64,
    /// chips drained + retired by the autoscaler
    pub scale_downs: u64,
    /// manual drain requests honored
    pub drains: u64,
}

/// Snapshot for one lane.
#[derive(Clone, Debug)]
pub struct LaneSnapshot {
    pub lane: Lane,
    pub requests: u64,
    pub errors: u64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub mean_batch: f64,
    pub energy_uj: f64,
}

impl Telemetry {
    pub fn new() -> Telemetry {
        let registry = Arc::new(MetricsRegistry::new());
        let stage = |name: &str| {
            registry.histogram(
                "imka_stage_us",
                "per-stage request latency breakdown (parse, queue, dispatch, \
                 lock_wait, analog_mvm, digital_combine, serialize)",
                &[("stage", name)],
                LogHistogram::latency_us,
            )
        };
        let stages = StageCells {
            parse: stage("parse"),
            queue: stage("queue"),
            dispatch: stage("dispatch"),
            lock_wait: stage("lock_wait"),
            analog_mvm: stage("analog_mvm"),
            digital_combine: stage("digital_combine"),
            serialize: stage("serialize"),
        };
        Telemetry { registry, lanes: RwLock::new(BTreeMap::new()), stages }
    }

    /// The registry every cell lives in (rendered by the `metrics` verb
    /// and reusable by benches for their own counters).
    pub fn registry(&self) -> &MetricsRegistry {
        &self.registry
    }

    /// Shared handle to the same registry — the observability hub holds
    /// one so canary gauges and alert states render in the same
    /// exposition as the lane counters.
    pub fn registry_arc(&self) -> Arc<MetricsRegistry> {
        Arc::clone(&self.registry)
    }

    fn lane_cells(&self, lane: Lane) -> Arc<LaneCells> {
        if let Some(cells) = self.lanes.read().unwrap().get(&lane) {
            return cells.clone();
        }
        let mut lanes = self.lanes.write().unwrap();
        lanes
            .entry(lane)
            .or_insert_with(|| {
                let label = lane.label();
                let l: &[(&str, &str)] = &[("lane", label.as_str())];
                Arc::new(LaneCells {
                    requests: self.registry.counter(
                        "imka_requests_total",
                        "requests served per lane",
                        l,
                    ),
                    errors: self.registry.counter(
                        "imka_request_errors_total",
                        "requests answered with an error per lane",
                        l,
                    ),
                    energy_uj: self.registry.counter(
                        "imka_lane_energy_uj_total",
                        "modelled AIMC energy of the analog portion, microjoules",
                        l,
                    ),
                    latency_us: self.registry.histogram(
                        "imka_lane_latency_us",
                        "end-to-end request latency (enqueue to reply)",
                        l,
                        LogHistogram::latency_us,
                    ),
                    batch: self.registry.histogram(
                        "imka_lane_batch_size",
                        "executed batch sizes per lane",
                        l,
                        LogHistogram::small_counts,
                    ),
                })
            })
            .clone()
    }

    /// Record one served request (hot path: read-lock + atomics only).
    pub fn record(&self, lane: Lane, latency_us: f64, batch: usize, energy_uj: f64, err: bool) {
        let cells = self.lane_cells(lane);
        cells.requests.inc();
        if err {
            cells.errors.inc();
        }
        cells.energy_uj.add(energy_uj.max(0.0));
        cells.latency_us.record(latency_us);
        cells.batch.record(batch as f64);
    }

    /// Record the per-request stages (zero/negative samples are skipped
    /// — e.g. in-process submitters have no parse stage).
    pub fn record_request_stages(&self, parse_us: f64, queue_us: f64) {
        if parse_us > 0.0 {
            self.stages.parse.record(parse_us);
        }
        if queue_us > 0.0 {
            self.stages.queue.record(queue_us);
        }
    }

    /// Record the per-batch stages measured by an executor. The dispatch
    /// stage is the substrate-routing decision and is measured on its
    /// own so the combine remainder can't silently absorb it; digital
    /// batches have no lock-wait/MVM stage and skip those samples.
    pub fn record_batch_stages(
        &self,
        dispatch_us: f64,
        lock_wait_us: f64,
        analog_mvm_us: f64,
        combine_us: f64,
    ) {
        if dispatch_us > 0.0 {
            self.stages.dispatch.record(dispatch_us);
        }
        if lock_wait_us > 0.0 {
            self.stages.lock_wait.record(lock_wait_us);
        }
        if analog_mvm_us > 0.0 {
            self.stages.analog_mvm.record(analog_mvm_us);
        }
        if combine_us > 0.0 {
            self.stages.digital_combine.record(combine_us);
        }
    }

    /// Record the reply-encoding stage measured by the server as it
    /// builds the wire bytes (the one stage that runs after the request
    /// completes; in-process submitters have none and never call this).
    pub fn record_serialize_stage(&self, us: f64) {
        if us > 0.0 {
            self.stages.serialize.record(us);
        }
    }

    pub fn snapshot(&self) -> Vec<LaneSnapshot> {
        let lanes = self.lanes.read().unwrap();
        lanes
            .iter()
            .map(|(lane, c)| LaneSnapshot {
                lane: *lane,
                requests: c.requests.get() as u64,
                errors: c.errors.get() as u64,
                p50_us: c.latency_us.p50(),
                p95_us: c.latency_us.p95(),
                p99_us: c.latency_us.p99(),
                mean_batch: c.batch.mean(),
                energy_uj: c.energy_uj.get(),
            })
            .collect()
    }

    pub fn total_requests(&self) -> u64 {
        let lanes = self.lanes.read().unwrap();
        lanes.values().map(|c| c.requests.get() as u64).sum()
    }
}

/// Live (scrape-time) gauges that complement the registry in the
/// `metrics` exposition: fleet totals, per-chip counters, control-plane
/// events, attention sessions, trace-sampling counters.
#[derive(Default)]
pub struct LiveGauges {
    pub chips: Vec<ChipSnapshot>,
    pub events: FleetEventsSnapshot,
    pub n_chips: usize,
    pub total_slots: usize,
    pub cores_used: usize,
    pub utilization: f64,
    pub inflight: usize,
    pub control_enabled: bool,
    pub sessions: Option<SessionStatsSnapshot>,
    /// (sample_every, spans sampled, spans dropped by the ring cap)
    pub trace: Option<(u64, u64, u64)>,
}

/// Render the full Prometheus-style text exposition: everything in
/// `registry` (lane + stage series) followed by the live gauges. The
/// `metrics` TCP verb returns exactly this text; `bench_attention_serve`
/// prints it in its smoke mode so CI can grep the gauge names.
pub fn render_metrics(registry: &MetricsRegistry, live: &LiveGauges) -> String {
    let mut out = registry.render();

    let gauge = |out: &mut String, name: &str, help: &str, v: f64| {
        out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n"));
        push_sample(out, name, &[], &[], v);
    };
    gauge(&mut out, "imka_fleet_chips", "active (non-evicted) chips", live.n_chips as f64);
    gauge(
        &mut out,
        "imka_fleet_slots",
        "slots ever created, including evicted tombstones",
        live.total_slots as f64,
    );
    gauge(&mut out, "imka_fleet_cores_used", "crossbar cores programmed fleet-wide", live.cores_used as f64);
    gauge(&mut out, "imka_fleet_utilization", "programmed cores / fleet capacity", live.utilization);
    gauge(
        &mut out,
        "imka_fleet_inflight",
        "analog MVMs in flight fleet-wide (sum of per-chip queue depths)",
        live.inflight as f64,
    );
    gauge(
        &mut out,
        "imka_fleet_control_enabled",
        "1 when the background control-plane loop is running",
        if live.control_enabled { 1.0 } else { 0.0 },
    );

    // control-plane event counters
    let counter = |out: &mut String, name: &str, help: &str, v: f64| {
        out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} counter\n"));
        push_sample(out, name, &[], &[], v);
    };
    counter(&mut out, "imka_fleet_evictions_total", "chips evicted", live.events.evictions as f64);
    counter(&mut out, "imka_fleet_scale_ups_total", "autoscaler scale-ups", live.events.scale_ups as f64);
    counter(
        &mut out,
        "imka_fleet_scale_downs_total",
        "autoscaler scale-downs",
        live.events.scale_downs as f64,
    );
    counter(&mut out, "imka_fleet_drains_total", "manual drains honored", live.events.drains as f64);

    // per-chip gauges/counters, one family block each
    let per_chip = |out: &mut String, name: &str, help: &str, kind: &str, f: &dyn Fn(&ChipSnapshot) -> f64| {
        out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
        for c in &live.chips {
            let chip = c.chip.to_string();
            push_sample(out, name, &[], &[("chip", chip.as_str())], f(c));
        }
    };
    per_chip(&mut out, "imka_chip_queue_depth", "MVMs queued on or executing against this chip", "gauge", &|c| c.queue_depth as f64);
    per_chip(&mut out, "imka_chip_busy_cores", "cores currently executing an MVM", "gauge", &|c| c.busy_cores as f64);
    per_chip(&mut out, "imka_chip_core_utilization", "busy cores / capacity, clamped to [0,1]", "gauge", &|c| c.core_utilization);
    per_chip(
        &mut out,
        "imka_chip_core_oversubscription",
        "same-replica overlap beyond core capacity (fraction of capacity)",
        "gauge",
        &|c| c.core_oversubscription,
    );
    per_chip(&mut out, "imka_chip_cores_used", "cores programmed on this chip", "gauge", &|c| c.cores_used as f64);
    per_chip(&mut out, "imka_chip_utilization", "programmed cores / capacity", "gauge", &|c| c.utilization);
    per_chip(&mut out, "imka_chip_served_total", "analog MVMs completed", "counter", &|c| c.served as f64);
    per_chip(&mut out, "imka_chip_errors_total", "failed MVMs/heartbeat probes", "counter", &|c| c.errors as f64);
    per_chip(&mut out, "imka_chip_recals_total", "recalibration cycles", "counter", &|c| c.recals as f64);
    per_chip(&mut out, "imka_chip_age_s", "fleet-clock seconds since last (re)programming", "gauge", &|c| c.age_s);
    per_chip(&mut out, "imka_chip_drift_err_estimate", "analytic drift-error estimate at current age", "gauge", &|c| c.drift_err_estimate);
    out.push_str(
        "# HELP imka_chip_health 1 for the chip's current control-plane state\n\
         # TYPE imka_chip_health gauge\n",
    );
    for c in &live.chips {
        let chip = c.chip.to_string();
        push_sample(&mut out, "imka_chip_health", &[], &[("chip", chip.as_str()), ("state", c.health)], 1.0);
    }

    if let Some(s) = &live.sessions {
        gauge(&mut out, "imka_attn_sessions_active", "streaming attention sessions open", s.active as f64);
        counter(&mut out, "imka_attn_sessions_opened_total", "sessions opened since boot", s.opened as f64);
        counter(&mut out, "imka_attn_sessions_closed_total", "sessions closed since boot", s.closed as f64);
        counter(&mut out, "imka_attn_tokens_total", "tokens streamed across all sessions", s.tokens as f64);
    }
    if let Some((every, sampled, dropped)) = live.trace {
        gauge(
            &mut out,
            "imka_trace_sample_every",
            "trace sampling rate (1 in N requests; 0 disables)",
            every as f64,
        );
        counter(&mut out, "imka_trace_sampled_total", "trace spans recorded", sampled as f64);
        counter(&mut out, "imka_trace_dropped_total", "trace spans overwritten by the ring cap", dropped as f64);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{KernelLane, PathLane};

    #[test]
    fn records_and_snapshots() {
        let t = Telemetry::default();
        let lane = Lane::Feature(KernelLane::Rbf, PathLane::Analog);
        for i in 0..10 {
            t.record(lane, 100.0 + i as f64, 4, 0.5, i == 9);
        }
        let snap = t.snapshot();
        assert_eq!(snap.len(), 1);
        let s = &snap[0];
        assert_eq!(s.requests, 10);
        assert_eq!(s.errors, 1);
        assert!((s.mean_batch - 4.0).abs() < 1e-9);
        // histogram quantiles are approximate within the bucket growth
        // factor (±~10%), unlike the exact Summary they replaced
        assert!(s.p50_us >= 90.0 && s.p50_us <= 120.0, "p50 {}", s.p50_us);
        assert!(s.p99_us >= 90.0 && s.p99_us <= 125.0, "p99 {}", s.p99_us);
        assert!((s.energy_uj - 5.0).abs() < 1e-9);
        assert_eq!(t.total_requests(), 10);
    }

    #[test]
    fn memory_is_bounded_per_lane() {
        // 100k requests must not grow per-lane state (the PR 2-6 sink
        // pushed every latency into a Vec)
        let t = Telemetry::new();
        let lane = Lane::Feature(KernelLane::Softmax, PathLane::Digital);
        for i in 0..100_000u64 {
            t.record(lane, (i % 1000) as f64 + 1.0, 8, 0.0, false);
        }
        assert_eq!(t.total_requests(), 100_000);
        let s = &t.snapshot()[0];
        assert!(s.p50_us.is_finite() && s.p99_us.is_finite());
        assert_eq!(t.lanes.read().unwrap().len(), 1);
    }

    #[test]
    fn concurrent_lanes_do_not_serialize_or_lose_counts() {
        use std::sync::Arc;
        let t = Arc::new(Telemetry::new());
        let lanes = [
            Lane::Feature(KernelLane::Rbf, PathLane::Analog),
            Lane::Feature(KernelLane::Rbf, PathLane::Digital),
            Lane::Feature(KernelLane::ArcCos0, PathLane::Analog),
            Lane::Performer(crate::coordinator::request::ModeLane::Fp32),
        ];
        let threads: Vec<_> = lanes
            .iter()
            .map(|&lane| {
                let t = Arc::clone(&t);
                std::thread::spawn(move || {
                    for i in 0..2000 {
                        t.record(lane, 50.0 + (i % 100) as f64, 2, 0.1, false);
                    }
                })
            })
            .collect();
        for th in threads {
            th.join().unwrap();
        }
        assert_eq!(t.total_requests(), 8000);
        assert_eq!(t.snapshot().len(), 4);
    }

    #[test]
    fn exposition_golden_shape() {
        let t = Telemetry::new();
        t.record(Lane::Feature(KernelLane::Rbf, PathLane::Analog), 120.0, 4, 0.5, false);
        t.record_request_stages(3.0, 40.0);
        t.record_batch_stages(0.8, 1.5, 60.0, 15.0);
        t.record_serialize_stage(7.0);
        let live = LiveGauges {
            chips: vec![ChipSnapshot {
                chip: 0,
                health: "healthy",
                cores_used: 4,
                utilization: 0.5,
                queue_depth: 2,
                busy_cores: 3,
                core_utilization: 0.375,
                core_oversubscription: 0.0,
                served: 11,
                errors: 0,
                recals: 1,
                age_s: 9.5,
                drift_err_estimate: 0.01,
            }],
            events: FleetEventsSnapshot { evictions: 1, scale_ups: 2, scale_downs: 0, drains: 3 },
            n_chips: 1,
            total_slots: 2,
            cores_used: 4,
            utilization: 0.5,
            inflight: 2,
            control_enabled: true,
            sessions: Some(SessionStatsSnapshot { active: 1, opened: 2, closed: 1, tokens: 64 }),
            trace: Some((8, 5, 0)),
        };
        let text = render_metrics(t.registry(), &live);

        for needle in [
            "# TYPE imka_lane_latency_us histogram",
            "imka_lane_latency_us_count{lane=\"feature_rbf_analog\"} 1",
            "imka_lane_batch_size_sum{lane=\"feature_rbf_analog\"} 4",
            "imka_requests_total{lane=\"feature_rbf_analog\"} 1",
            "imka_lane_energy_uj_total{lane=\"feature_rbf_analog\"} 0.5",
            "imka_stage_us_count{stage=\"queue\"} 1",
            "imka_stage_us_count{stage=\"dispatch\"} 1",
            "imka_stage_us_count{stage=\"analog_mvm\"} 1",
            "imka_stage_us_count{stage=\"serialize\"} 1",
            "# TYPE imka_fleet_inflight gauge",
            "imka_fleet_inflight 2",
            "imka_fleet_chips 1",
            "imka_fleet_control_enabled 1",
            "imka_fleet_evictions_total 1",
            "imka_fleet_drains_total 3",
            "imka_chip_queue_depth{chip=\"0\"} 2",
            "imka_chip_busy_cores{chip=\"0\"} 3",
            "imka_chip_core_utilization{chip=\"0\"} 0.375",
            "imka_chip_core_oversubscription{chip=\"0\"} 0",
            "imka_chip_served_total{chip=\"0\"} 11",
            "imka_chip_health{chip=\"0\",state=\"healthy\"} 1",
            "imka_attn_sessions_active 1",
            "imka_attn_tokens_total 64",
            "imka_trace_sample_every 8",
            "imka_trace_sampled_total 5",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        // every non-comment line is `name{...} value` with a numeric value
        for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            let (_, val) = line.rsplit_once(' ').unwrap();
            assert!(val.parse::<f64>().is_ok(), "bad exposition line: {line}");
        }
    }
}
