//! The serving engine: ingress queue → dynamic batcher → substrate
//! dispatcher → { worker pool (features/performer) | session-sharded
//! attention executors } → (analog chip fan-out | native digital matmul
//! | XLA artifacts | session state) → replies. Every batch of
//! substrate-flexible work (analog feature requests, analog attention
//! sessions) is scored by the [`crate::fleet::dispatch`] cost model and
//! runs on whichever substrate is cheaper; digital requests keep their
//! exact-fp32 contract and always execute natively. The leader
//! (`Engine::start`) programs the chip and spawns the threads; workers
//! never touch Python — the request path is pure Rust (+ PJRT for the
//! performer lane only).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicI32, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use super::batcher::{answer_shutdown, run_batcher, Batch};
use super::request::{
    KernelLane, Lane, ModeLane, PathKind, PathLane, PerfMode, Request, RequestBody, Response,
    ResponseBody,
};
use super::session::{AttnSessionInfo, SessionManager, SessionStatsSnapshot};
use super::telemetry::{
    render_metrics, ChipSnapshot, FleetEventsSnapshot, LaneSnapshot, LiveGauges, Telemetry,
};
use super::tilepool::lane_omega;
use crate::aimc::Emulator;
use crate::config::Config;
use crate::energy::{latency_energy, mapping_energy_uj, mapping_ops, Device};
use crate::error::{Error, Result};
use crate::fleet::{ControlPlane, Dispatcher, FleetPool, HealthState, RecalScheduler, Substrate};
use crate::kernels::Kernel;
use crate::linalg::Mat;
use crate::obsv::{
    AlertInstance, Event, MvmProfile, ObservabilityHub, SeriesPoint, TraceRing, TraceSpan,
};
use crate::runtime::{ModelBundle, Registry};
use crate::util::Rng;

/// Feature-lane geometry, read from the artifact manifest.
#[derive(Clone, Copy, Debug)]
pub struct LaneGeometry {
    pub d: usize,
    pub m: usize,
    pub out_dim: usize,
}

struct Shared {
    registry: Registry,
    bundle: Option<ModelBundle>,
    pool: FleetPool,
    /// is the fleet control plane loop running (for the health surface)
    control_enabled: bool,
    geometries: BTreeMap<KernelLane, LaneGeometry>,
    /// emulator-programmed noisy Ω for the performer hw paths
    noisy_omega: Option<Mat>,
    /// emulator-programmed noisy 2-D params (hw_full)
    noisy_params: BTreeMap<String, Mat>,
    /// streaming-attention session registry (state off-chip, φ lanes on
    /// the fleet)
    sessions: SessionManager,
    telemetry: Telemetry,
    /// per-batch substrate router: cost model + measured EWMA
    /// calibration + the `imka_dispatch_*` metrics (`fleet::dispatch`)
    dispatch: Dispatcher,
    /// canaries + time-series rings + SLO alerts + event journal, built
    /// over the telemetry registry (`series`/`alerts`/`events` verbs)
    obsv: Arc<ObservabilityHub>,
    /// bounded ring of sampled per-request trace spans (`trace` verb)
    trace: TraceRing,
    /// wire policy the TCP server applies per connection (mode,
    /// frame/line caps, idle timeout), derived from `[serve]` at boot
    wire: crate::wire::WireConfig,
    /// engine-wide request-id source (Submitter clones share it)
    ids: AtomicU64,
    seed_ctr: AtomicI32,
    classes: usize,
}

/// Handle for submitting requests (clone freely across threads).
/// Assigns every request its engine-wide id and its trace-sampling
/// decision at submission, so the id a caller gets back in the reply is
/// enough to look up the span via the `trace` verb.
#[derive(Clone)]
pub struct Submitter {
    tx: mpsc::Sender<Request>,
    shared: Arc<Shared>,
}

impl Submitter {
    fn request(&self, body: RequestBody, parse_us: f64, reply: mpsc::SyncSender<Response>) -> Request {
        let id = self.shared.ids.fetch_add(1, Ordering::Relaxed);
        let trace = self.shared.trace.sampled(id);
        Request { body, reply, enqueued: Instant::now(), id, parse_us, trace }
    }

    /// Submit and wait for the reply (simple blocking client).
    pub fn call(&self, body: RequestBody) -> Result<Response> {
        self.call_parsed(body, 0.0)
    }

    /// Like [`Submitter::call`] but records the caller-measured parse
    /// time (µs) as the span's `parse` stage (the TCP server uses this).
    pub fn call_parsed(&self, body: RequestBody, parse_us: f64) -> Result<Response> {
        let (reply, rx) = mpsc::sync_channel(1);
        let req = self.request(body, parse_us, reply);
        self.tx
            .send(req)
            .map_err(|_| Error::Coordinator("engine is shut down".into()))?;
        rx.recv()
            .map_err(|_| Error::Coordinator("engine dropped the request".into()))
    }

    /// Fire-and-forget with caller-held reply channel (for load drivers).
    pub fn submit(&self, body: RequestBody) -> Result<mpsc::Receiver<Response>> {
        let (reply, rx) = mpsc::sync_channel(1);
        let req = self.request(body, 0.0, reply);
        self.tx
            .send(req)
            .map_err(|_| Error::Coordinator("engine is shut down".into()))?;
        Ok(rx)
    }
}

/// Running engine: threads + shared state.
pub struct Engine {
    shared: Arc<Shared>,
    ingress: mpsc::Sender<Request>,
    stop: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Engine {
    /// Boot the coordinator: open artifacts, load the trained model (if
    /// present), program the chip, spawn batcher + workers.
    pub fn start(cfg: &Config) -> Result<Engine> {
        let registry = Registry::open(std::path::Path::new(&cfg.artifacts_dir))?;

        // trained performer bundle (optional — feature serving works
        // without it)
        let bundle = {
            let weights = registry
                .manifest
                .get("weights")
                .and_then(|v| v.as_str())
                .map(|s| s.to_string());
            let testset = registry
                .manifest
                .get("testset")
                .and_then(|v| v.as_str())
                .map(|s| s.to_string());
            match (weights, testset) {
                (Some(w), Some(t)) => {
                    ModelBundle::load(std::path::Path::new(&cfg.artifacts_dir), &w, &t).ok()
                }
                _ => None,
            }
        };

        // program one Ω per feature lane present in the manifest, placed
        // across the configured fleet of chips
        let pool = FleetPool::new(cfg.chip.clone(), cfg.fleet.clone(), 0xC41B);
        let mut geometries = BTreeMap::new();
        let mut rng = Rng::new(0xCA11);
        for spec in registry.of_kind("feature_map") {
            let kernel = spec
                .meta
                .get("kernel")
                .and_then(|k| k.as_str())
                .and_then(Kernel::parse)
                .ok_or_else(|| Error::Artifact(format!("{}: bad kernel", spec.name)))?;
            let lane: KernelLane = kernel.into();
            if geometries.contains_key(&lane) {
                continue;
            }
            let d = spec.meta.req_usize("d")?;
            let m = spec.meta.req_usize("m")?;
            let out_dim = spec.out_dim().unwrap_or(kernel.l() * m);
            let omega = lane_omega(lane, d, m, 7);
            // calibration inputs: normalized data is ~N(0,1)
            let x_cal = Mat::randn(256, d, &mut rng);
            pool.program_lane(lane, omega, &x_cal, cfg.serve.replication)?;
            geometries.insert(lane, LaneGeometry { d, m, out_dim });
        }

        // emulator-programmed noisy weights for the performer hw modes
        let (noisy_omega, noisy_params) = if let Some(b) = &bundle {
            let mut rng = Rng::new(0x5EED);
            let om = Emulator::program(&b.omega, &cfg.chip, &mut rng).w_hat;
            let mut params = BTreeMap::new();
            for name in b.matrix_param_names() {
                let w = b.param_mat(&name)?;
                params.insert(name.clone(), Emulator::program(&w, &cfg.chip, &mut rng).w_hat);
            }
            (Some(om), params)
        } else {
            (None, BTreeMap::new())
        };

        let classes = registry
            .model_config()
            .and_then(|m| m.get("classes"))
            .and_then(|v| v.as_usize())
            .unwrap_or(2);

        // the hub shares the telemetry registry, so canary gauges and
        // alert states render in the same `metrics` exposition as the
        // lane counters
        let telemetry = Telemetry::default();
        let obsv = Arc::new(ObservabilityHub::new(telemetry.registry_arc(), &cfg.obsv));
        let dispatch = Dispatcher::new(cfg.dispatch.clone(), telemetry.registry());
        let shared = Arc::new(Shared {
            registry,
            bundle,
            pool,
            control_enabled: cfg.fleet.control.enabled,
            geometries,
            noisy_omega,
            noisy_params,
            sessions: SessionManager::new(cfg.attention.serve.clone(), cfg.serve.replication),
            telemetry,
            dispatch,
            obsv,
            trace: TraceRing::new(cfg.obsv.trace_buffer, cfg.obsv.trace_sample_every),
            wire: crate::wire::WireConfig::from_serve(&cfg.serve),
            ids: AtomicU64::new(1),
            seed_ctr: AtomicI32::new(1),
            classes,
        });

        // threads: 1 batcher + 1 dispatcher + N pool workers + A
        // attention executors. The dispatcher routes batches by workload:
        // feature/performer batches fan out over the worker pool
        // (stateless — any order is fine), while attention batches route
        // to the executor owning their session (session id mod A), so
        // batches of one session are processed in exactly the batcher's
        // emission order (two pool workers holding two batches of one
        // session could otherwise fold tokens out of order into its
        // running state) while distinct sessions still run concurrently.
        let queue_cap = cfg.serve.queue_cap.max(16);
        let (ingress_tx, ingress_rx) = mpsc::channel::<Request>();
        let (batch_tx, batch_rx) = mpsc::sync_channel::<Batch>(queue_cap);
        let (work_tx, work_rx) = mpsc::sync_channel::<Batch>(queue_cap);
        let work_rx = Arc::new(Mutex::new(work_rx));

        let mut threads = Vec::new();
        let stop = Arc::new(AtomicBool::new(false));
        let serve_cfg = cfg.serve.clone();
        let stop_b = stop.clone();
        threads.push(std::thread::spawn(move || {
            run_batcher(ingress_rx, batch_tx, &serve_cfg, stop_b)
        }));
        let attn_workers = cfg.serve.workers.clamp(1, 4);
        let mut attn_txs = Vec::with_capacity(attn_workers);
        for _ in 0..attn_workers {
            let (tx, rx) = mpsc::sync_channel::<Batch>(queue_cap);
            attn_txs.push(tx);
            let shared = shared.clone();
            threads.push(std::thread::spawn(move || {
                while let Ok(b) = rx.recv() {
                    execute_batch(&shared, b);
                }
            }));
        }
        threads.push(std::thread::spawn(move || {
            // single-threaded routing keeps per-session FIFO order intact
            while let Ok(batch) = batch_rx.recv() {
                let dst = match batch.lane {
                    Lane::Attention(s) => &attn_txs[(s.0 % attn_txs.len() as u64) as usize],
                    _ => &work_tx,
                };
                if let Err(mpsc::SendError(dead)) = dst.send(batch) {
                    // that executor is gone (shutdown): answer instead
                    // of dropping, then keep draining the rest
                    answer_shutdown(dead.requests);
                }
            }
        }));
        for _ in 0..cfg.serve.workers.max(1) {
            let shared = shared.clone();
            let rx = work_rx.clone();
            threads.push(std::thread::spawn(move || loop {
                let batch = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                match batch {
                    Ok(b) => execute_batch(&shared, b),
                    Err(_) => break,
                }
            }));
        }

        // background supervision. With the control plane enabled, one
        // loop runs the full tick (health probes + eviction/re-placement,
        // drift recalibration behind a Draining flag, queue-driven
        // autoscaling); otherwise the PR-2 recal-only loop is kept. In
        // both cases the fleet clock advances in wall time and at most
        // one chip is locked for rewriting at a time, so replicas keep
        // serving throughout.
        if cfg.fleet.control.enabled {
            let shared = shared.clone();
            let stop_c = stop.clone();
            let interval = cfg.fleet.control.interval_s.max(0.05);
            let scrape_interval = cfg.obsv.scrape_interval_s.max(0.05);
            let mut plane = ControlPlane::new(&cfg.fleet, &cfg.chip);
            plane.attach_observability(shared.obsv.clone());
            threads.push(std::thread::spawn(move || {
                let mut last = Instant::now();
                let mut last_scrape = Instant::now();
                while !stop_c.load(Ordering::Relaxed) {
                    // short sleeps keep shutdown latency bounded
                    std::thread::sleep(Duration::from_millis(50));
                    let dt = last.elapsed().as_secs_f64();
                    if dt < interval {
                        continue;
                    }
                    last = Instant::now();
                    shared.pool.advance_clock(dt);
                    match plane.tick(&shared.pool) {
                        Ok(report) if !report.is_quiet() => {
                            eprintln!("fleet control: {report}");
                        }
                        Ok(_) => {}
                        Err(e) => eprintln!("fleet control tick failed: {e}"),
                    }
                    // scrape on the wall clock, but only after a tick —
                    // the fleet clock just advanced, so series points
                    // and rate denominators stay strictly monotone
                    if last_scrape.elapsed().as_secs_f64() >= scrape_interval {
                        last_scrape = Instant::now();
                        plane.scrape(&shared.pool);
                    }
                }
            }));
        } else if cfg.fleet.recal_interval_s > 0.0 {
            let shared = shared.clone();
            let stop_r = stop.clone();
            let interval = cfg.fleet.recal_interval_s;
            let scheduler = RecalScheduler::new(cfg.fleet.drift_err_budget);
            threads.push(std::thread::spawn(move || {
                let mut last = Instant::now();
                while !stop_r.load(Ordering::Relaxed) {
                    // short sleeps keep shutdown latency bounded
                    std::thread::sleep(Duration::from_millis(50));
                    let dt = last.elapsed().as_secs_f64();
                    if dt < interval {
                        continue;
                    }
                    last = Instant::now();
                    shared.pool.advance_clock(dt);
                    match scheduler.tick(&shared.pool) {
                        Ok(chips) if !chips.is_empty() => {
                            eprintln!("recalibrated drifted chips: {chips:?}");
                        }
                        Ok(_) => {}
                        Err(e) => eprintln!("recalibration tick failed: {e}"),
                    }
                }
            }));
        }

        let engine = Engine { shared, ingress: ingress_tx, stop, threads };
        if cfg.serve.warm {
            engine.warm();
        }
        Ok(engine)
    }

    /// Eagerly compile the artifacts the request path will hit, so first
    /// requests don't pay XLA compile latency (§Perf: p95/p99 of the e2e
    /// driver dropped from seconds to the steady-state batch time). The
    /// feature lanes run natively on both substrates now, so only the
    /// performer — whose forward exists solely as XLA programs — warms.
    fn warm(&self) {
        let primary_task = self
            .shared
            .registry
            .manifest
            .get("task")
            .and_then(|v| v.as_str())
            .unwrap_or("pattern")
            .to_string();
        let names: Vec<String> = self
            .shared
            .registry
            .specs
            .values()
            .filter(|s| {
                s.kind.as_str() == "performer"
                    && s.meta.get("task").and_then(|t| t.as_str()) == Some(primary_task.as_str())
            })
            .map(|s| s.name.clone())
            .collect();
        for name in names {
            if let Err(e) = self.shared.registry.load(&name) {
                eprintln!("warm-compile of {name} failed: {e}");
            }
        }
    }

    pub fn submitter(&self) -> Submitter {
        Submitter { tx: self.ingress.clone(), shared: self.shared.clone() }
    }

    pub fn telemetry(&self) -> &Telemetry {
        &self.shared.telemetry
    }

    /// Cloneable, thread-safe view over serving + fleet statistics (the
    /// TCP server hands one to every connection for `stats` requests).
    pub fn stats_handle(&self) -> StatsHandle {
        StatsHandle { shared: self.shared.clone() }
    }

    /// Cloneable handle for attention-session control operations
    /// (`attn_open` / `attn_close`); appends travel the batched request
    /// path via [`Submitter`].
    pub fn sessions_handle(&self) -> SessionsHandle {
        SessionsHandle { shared: self.shared.clone() }
    }

    /// The wire policy (`[serve] wire` / frame caps / idle timeout) the
    /// TCP server applies to every connection it accepts.
    pub fn wire_config(&self) -> crate::wire::WireConfig {
        self.shared.wire.clone()
    }

    pub fn cores_used(&self) -> usize {
        self.shared.pool.cores_used()
    }

    pub fn n_chips(&self) -> usize {
        self.shared.pool.n_chips()
    }

    pub fn fleet_utilization(&self) -> f64 {
        self.shared.pool.utilization()
    }

    pub fn has_model(&self) -> bool {
        self.shared.bundle.is_some()
    }

    pub fn classes(&self) -> usize {
        self.shared.classes
    }

    pub fn seq_len(&self) -> Option<usize> {
        self.shared.bundle.as_ref().map(|b| b.seq_len)
    }

    /// Graceful shutdown: raise the stop flag (live Submitter clones may
    /// still hold ingress senders), close our sender, join all threads.
    pub fn shutdown(self) {
        self.stop.store(true, Ordering::Relaxed);
        drop(self.ingress);
        for t in self.threads {
            let _ = t.join();
        }
    }
}

/// Read-only statistics view shared with server connection handlers.
#[derive(Clone)]
pub struct StatsHandle {
    shared: Arc<Shared>,
}

impl StatsHandle {
    pub fn lanes(&self) -> Vec<LaneSnapshot> {
        self.shared.telemetry.snapshot()
    }

    pub fn chips(&self) -> Vec<ChipSnapshot> {
        self.shared.pool.chip_snapshots()
    }

    /// Active (non-evicted) chips.
    pub fn n_chips(&self) -> usize {
        self.shared.pool.n_chips()
    }

    /// All slots ever created, including evicted tombstones.
    pub fn total_slots(&self) -> usize {
        self.shared.pool.total_slots()
    }

    pub fn cores_used(&self) -> usize {
        self.shared.pool.cores_used()
    }

    pub fn utilization(&self) -> f64 {
        self.shared.pool.utilization()
    }

    pub fn total_requests(&self) -> u64 {
        self.shared.telemetry.total_requests()
    }

    /// Analog MVMs in flight across the fleet right now (sum of the
    /// per-chip atomic gauges — no chip lock taken, so `stats` never
    /// blocks behind an MVM or a GDP rewrite).
    pub fn total_inflight(&self) -> usize {
        self.shared.pool.total_queue_depth()
    }

    /// Is the background control-plane loop running?
    pub fn control_enabled(&self) -> bool {
        self.shared.control_enabled
    }

    /// Control-plane event counters (evictions, scale events, drains).
    pub fn fleet_events(&self) -> FleetEventsSnapshot {
        self.shared.pool.events()
    }

    /// The full Prometheus-style text exposition (the `metrics` verb):
    /// every registry series (lane counters/histograms, stage
    /// histograms, bench counters) plus scrape-time fleet/chip/session/
    /// trace gauges.
    pub fn metrics_text(&self) -> String {
        let (sampled, dropped) = self.shared.trace.counts();
        let live = LiveGauges {
            chips: self.shared.pool.chip_snapshots(),
            events: self.shared.pool.events(),
            n_chips: self.shared.pool.n_chips(),
            total_slots: self.shared.pool.total_slots(),
            cores_used: self.shared.pool.cores_used(),
            utilization: self.shared.pool.utilization(),
            inflight: self.shared.pool.total_queue_depth(),
            control_enabled: self.shared.control_enabled,
            sessions: Some(self.shared.sessions.snapshot()),
            trace: Some((self.shared.trace.sample_every(), sampled, dropped)),
        };
        render_metrics(self.shared.telemetry.registry(), &live)
    }

    /// Newest-first sampled trace spans (the `trace` verb).
    pub fn traces(&self, limit: usize) -> Vec<TraceSpan> {
        self.shared.trace.latest(limit)
    }

    /// Trace-sampling counters: (sample_every, spans recorded, spans
    /// overwritten by the ring cap).
    pub fn trace_counts(&self) -> (u64, u64, u64) {
        let (sampled, dropped) = self.shared.trace.counts();
        (self.shared.trace.sample_every(), sampled, dropped)
    }

    /// Trace-ring capacity — the `trace` verb clamps its limit to this.
    pub fn trace_cap(&self) -> usize {
        self.shared.trace.cap()
    }

    /// Record the reply-encoding time the server measured for one
    /// request: always feeds the `serialize` stage histogram, and — when
    /// the request id is known and was trace-sampled — patches the
    /// already-pushed span so the `trace` verb shows `serialize_us`.
    pub fn record_serialize(&self, request_id: Option<u64>, us: f64) {
        self.shared.telemetry.record_serialize_stage(us);
        if let Some(id) = request_id {
            if self.shared.trace.sampled(id) {
                self.shared.trace.attach_serialize(id, us);
            }
        }
    }

    /// Time-series keys starting with `prefix` ("" = all), sorted (the
    /// `series` verb).
    pub fn series_keys(&self, prefix: &str) -> Vec<String> {
        self.shared.obsv.series().keys_matching(prefix)
    }

    /// Newest `n` points of one series, oldest-first.
    pub fn series_points(&self, key: &str, n: usize) -> Vec<SeriesPoint> {
        let pts = self.shared.obsv.series().get(key);
        let skip = pts.len().saturating_sub(n);
        pts.into_iter().skip(skip).collect()
    }

    /// Current SLO alert instances, ordered by (rule, series) (the
    /// `alerts` verb).
    pub fn alerts(&self) -> Vec<AlertInstance> {
        self.shared.obsv.alert_states()
    }

    /// Journal entries with `seq >= since`, plus (oldest retained seq,
    /// next seq to be assigned). `first_seq > since` tells a pager that
    /// the bounded ring dropped entries it never saw.
    pub fn events_since(&self, since: u64) -> (Vec<Event>, u64, u64) {
        let j = self.shared.obsv.journal();
        let next = j.next_seq();
        (j.since(since), j.first_seq().unwrap_or(next), next)
    }

    /// Mark a chip `Draining` (the `drain` TCP verb): traffic is steered
    /// to replicas on other chips while the chip stays programmed.
    pub fn drain_chip(&self, chip: usize) -> Result<HealthState> {
        if chip >= self.shared.pool.total_slots() {
            return Err(Error::Coordinator(format!("no chip {chip}")));
        }
        self.shared.pool.drain_chip(chip)?;
        Ok(self.shared.pool.chip_health(chip))
    }

    /// Return a drained chip to service (the `drain` verb with
    /// `"undrain": true`).
    pub fn undrain_chip(&self, chip: usize) -> Result<HealthState> {
        if chip >= self.shared.pool.total_slots() {
            return Err(Error::Coordinator(format!("no chip {chip}")));
        }
        self.shared.pool.undrain_chip(chip)?;
        Ok(self.shared.pool.chip_health(chip))
    }
}

/// Control-plane view over the attention-session registry, shared with
/// server connection handlers (mirrors [`StatsHandle`]).
#[derive(Clone)]
pub struct SessionsHandle {
    shared: Arc<Shared>,
}

impl SessionsHandle {
    /// Open a streaming session (`attn_open`). `path` falls back to the
    /// `[attention.serve] path` default; an analog open lazily programs
    /// the per-head Ω lanes onto the fleet.
    pub fn open(&self, path: Option<PathKind>) -> Result<AttnSessionInfo> {
        self.shared.sessions.open(&self.shared.pool, path)
    }

    /// Close a session (`attn_close`); returns its streamed token count.
    pub fn close(&self, id: u64) -> Result<usize> {
        self.shared.sessions.close(id)
    }

    /// Aggregate session counters (the `stats` response's `attention`
    /// section).
    pub fn stats(&self) -> SessionStatsSnapshot {
        self.shared.sessions.snapshot()
    }
}

// ---------------------------------------------------------------------------
// batch execution (one executor per workload; the batcher guarantees a
// batch is lane-homogeneous, so dispatch is a single match)
// ---------------------------------------------------------------------------

/// Per-batch stage breakdown, measured once and shared by every request
/// in the batch: dispatch is the substrate-routing cost model, the
/// executor's lock-wait and analog-MVM time come from the
/// [`MvmProfile`] the fleet fan-out fills, and everything else the
/// executor spent (gather/validate, native matmul/postprocess, XLA
/// artifacts) is the digital-combine stage.
#[derive(Clone, Copy)]
struct BatchStages {
    dispatch_us: f64,
    lock_wait_us: f64,
    analog_mvm_us: f64,
    digital_combine_us: f64,
}

/// Highest drift-error estimate across the live (non-evicted) fleet —
/// the dispatcher's accuracy signal: a drifted fleet degrades analog
/// results, so the cost model inflates (or cuts off) the analog side.
fn fleet_drift_err(shared: &Shared) -> f64 {
    shared
        .pool
        .chip_snapshots()
        .iter()
        .filter(|c| c.health != "evicted")
        .map(|c| c.drift_err_estimate)
        .fold(0.0, f64::max)
}

/// Score one batch against the dispatch cost model: the chosen substrate
/// and the row count scored, or `None` for lanes that never route
/// (performer: its forward exists only as XLA programs). Requests that
/// pin the digital path (exact-fp32 contract) bypass the model — force
/// only constrains substrate-flexible analog work — but still return
/// `Digital` so their measured latency calibrates the digital EWMA.
fn route_batch(shared: &Shared, batch: &Batch) -> Option<(Substrate, usize)> {
    match batch.lane {
        Lane::Feature(lane, path) => {
            let geo = shared.geometries.get(&lane)?;
            let n = batch.requests.len().max(1);
            match path {
                PathLane::Digital => Some((Substrate::Digital, n)),
                PathLane::Analog => {
                    let drift = fleet_drift_err(shared);
                    let queue = shared.pool.total_queue_depth();
                    Some((shared.dispatch.decide(n, geo.d, geo.m, drift, queue), n))
                }
            }
        }
        Lane::Performer(_) => None,
        Lane::Attention(session) => {
            let s = shared.sessions.get(session.0).ok()?;
            let a = shared.sessions.config();
            // every token projects its q and k rows through each head
            let rows = 2 * batch.requests.len().max(1) * a.heads;
            match s.path {
                PathKind::Digital => Some((Substrate::Digital, rows)),
                PathKind::Analog => {
                    let drift = fleet_drift_err(shared);
                    let queue = shared.pool.total_queue_depth();
                    Some((shared.dispatch.decide(rows, a.d_head, a.m, drift, queue), rows))
                }
            }
        }
    }
}

fn execute_batch(shared: &Shared, batch: Batch) {
    let n = batch.requests.len();
    let exec_start = Instant::now();
    // substrate routing, timed as its own stage so the cost model's
    // overhead stays visible instead of folding into digital_combine
    let route = route_batch(shared, &batch);
    let dispatch_us = exec_start.elapsed().as_secs_f64() * 1e6;
    let substrate = route.map(|(s, _)| s);
    let prof = MvmProfile::default();
    let result = match batch.lane {
        Lane::Feature(kernel, path) => {
            run_feature_batch(shared, kernel, path, substrate, &batch, &prof)
        }
        Lane::Performer(mode) => run_performer_batch(shared, mode, &batch),
        Lane::Attention(session) => {
            run_attention_batch(shared, session.0, substrate, &batch, &prof)
        }
    };
    let lane_key = batch.lane.telemetry_key();
    let lane_label = batch.lane.label();
    let exec_us = exec_start.elapsed().as_secs_f64() * 1e6;
    if result.is_ok() {
        if let Some((sub, rows)) = route {
            // measured feedback: per-row EWMA calibration plus the
            // imka_dispatch_latency_us{substrate} histogram
            shared.dispatch.observe(sub, exec_us, rows);
        }
    }
    let stages = BatchStages {
        dispatch_us,
        lock_wait_us: prof.lock_wait_us(),
        analog_mvm_us: prof.mvm_us(),
        digital_combine_us: (exec_us - dispatch_us - prof.lock_wait_us() - prof.mvm_us())
            .max(0.0),
    };
    shared.telemetry.record_batch_stages(
        stages.dispatch_us,
        stages.lock_wait_us,
        stages.analog_mvm_us,
        stages.digital_combine_us,
    );
    match result {
        Ok((bodies, energy_uj)) => {
            debug_assert_eq!(bodies.len(), n);
            for (req, body) in batch.requests.into_iter().zip(bodies) {
                finish_request(
                    shared,
                    req,
                    Ok(body),
                    energy_uj / n as f64,
                    n,
                    lane_key,
                    &lane_label,
                    exec_start,
                    stages,
                );
            }
        }
        Err(e) => {
            let msg = e.to_string();
            for req in batch.requests {
                finish_request(
                    shared,
                    req,
                    Err(Error::Coordinator(msg.clone())),
                    0.0,
                    n,
                    lane_key,
                    &lane_label,
                    exec_start,
                    stages,
                );
            }
        }
    }
}

/// Tail of every request: record telemetry + stages, push a trace span
/// if the request id was sampled, send the reply.
#[allow(clippy::too_many_arguments)]
fn finish_request(
    shared: &Shared,
    req: Request,
    result: Result<ResponseBody>,
    energy_uj: f64,
    batch_size: usize,
    lane_key: Lane,
    lane_label: &str,
    exec_start: Instant,
    stages: BatchStages,
) {
    let latency_us = req.enqueued.elapsed().as_secs_f64() * 1e6;
    // saturates to 0 if the batch started before this request enqueued
    let queue_us = exec_start.duration_since(req.enqueued).as_secs_f64() * 1e6;
    let ok = result.is_ok();
    shared.telemetry.record(lane_key, latency_us, batch_size, energy_uj, !ok);
    shared.telemetry.record_request_stages(req.parse_us, queue_us);
    if req.trace {
        shared.trace.push(TraceSpan {
            request_id: req.id,
            lane: lane_label.to_string(),
            batch: batch_size,
            ok,
            parse_us: req.parse_us,
            queue_us,
            dispatch_us: stages.dispatch_us,
            lock_wait_us: stages.lock_wait_us,
            analog_mvm_us: stages.analog_mvm_us,
            digital_combine_us: stages.digital_combine_us,
            total_us: latency_us,
        });
    }
    let _ = req.reply.send(Response {
        result,
        latency_us,
        energy_uj,
        batch_size,
        request_id: req.id,
    });
}

/// Attention lane: stream the batch's tokens into the session in arrival
/// order. The φ(q)/φ(k) projections run batched per head on the
/// substrate the dispatcher chose — an analog session's small or
/// drift-exposed batch may execute digitally against the same Ω twins,
/// so the running state stays coherent across switches. The running-sum
/// update and normalization are native Rust against off-chip state.
fn run_attention_batch(
    shared: &Shared,
    session: u64,
    substrate: Option<Substrate>,
    batch: &Batch,
    prof: &MvmProfile,
) -> Result<(Vec<ResponseBody>, f64)> {
    let mut items: Vec<(&[f32], &[f32], &[f32])> = Vec::with_capacity(batch.requests.len());
    for req in &batch.requests {
        match &req.body {
            RequestBody::AttnAppend { q, k, v, .. } => {
                items.push((q.as_slice(), k.as_slice(), v.as_slice()))
            }
            _ => return Err(Error::Coordinator("mixed lane".into())),
        }
    }
    let n = items.len();
    let session = shared.sessions.get(session)?;
    // the dispatcher only ever downgrades analog→digital; a session
    // opened digital never touches the chip
    let exec_path = if session.path == PathKind::Analog && substrate == Some(Substrate::Analog) {
        PathKind::Analog
    } else {
        PathKind::Digital
    };
    let outs =
        shared.sessions.append_to_on(&shared.pool, &session, &items, Some(prof), exec_path)?;

    // modelled AIMC energy: on the analog path every token's q and k
    // project through each head's Ω lane on-chip
    let energy_uj = if exec_path == PathKind::Analog {
        let a = shared.sessions.config();
        let ops = 2.0 * a.heads as f64 * mapping_ops(n, a.d_head, a.m);
        let (_, e_mj) = latency_energy(ops, &Device::Aimc.spec());
        e_mj * 1e3
    } else {
        0.0
    };

    let bodies = outs
        .into_iter()
        .map(|(y, index)| ResponseBody::AttnOut { y, index })
        .collect();
    Ok((bodies, energy_uj))
}

/// Feature lane: both substrates execute artifact-free. Digital = native
/// φ(x) through `linalg::matmul` against the lane's digital-twin Ω
/// ([`crate::runtime::native`]); analog = chip MVM + native postprocess
/// for all three kernels. A digital *request* is an exact-fp32 contract
/// and always runs digitally; an analog request runs on whichever
/// substrate the dispatcher routed its batch to.
fn run_feature_batch(
    shared: &Shared,
    lane: KernelLane,
    path: PathLane,
    substrate: Option<Substrate>,
    batch: &Batch,
    prof: &MvmProfile,
) -> Result<(Vec<ResponseBody>, f64)> {
    let kernel = lane.kernel();
    let geo = shared
        .geometries
        .get(&lane)
        .ok_or_else(|| Error::Coordinator(format!("no geometry for {lane:?}")))?;
    let n = batch.requests.len();

    // gather + validate
    let mut x = Mat::zeros(n, geo.d);
    for (i, req) in batch.requests.iter().enumerate() {
        match &req.body {
            RequestBody::Features { x: row, .. } => {
                if row.len() != geo.d {
                    return Err(Error::Shape(format!(
                        "feature request has {} dims, lane expects {}",
                        row.len(),
                        geo.d
                    )));
                }
                x.row_mut(i).copy_from_slice(row);
            }
            _ => return Err(Error::Coordinator("mixed lane".into())),
        }
    }

    let mapping = shared.pool.mapping(lane)?;
    let (z, energy_uj) = match (path, substrate) {
        (PathLane::Analog, Some(Substrate::Analog)) => {
            // chip MVM (whole batch at once), then the native digital
            // half; modelled AIMC energy of the mapping (Supp. Table
            // VIII method)
            let u = shared.pool.project_with(lane, &x, Some(prof))?;
            let z = crate::runtime::native::analog_postprocess(kernel, &u, &x);
            (z, mapping_energy_uj(n, geo.d, geo.m, &Device::Aimc.spec()))
        }
        _ => {
            let z = crate::runtime::native::feature_forward(kernel, &x, &mapping.omega);
            (z, 0.0)
        }
    };

    let bodies = (0..n)
        .map(|i| ResponseBody::Features(z.row(i).to_vec()))
        .collect();
    Ok((bodies, energy_uj))
}

/// Performer lane: pick the artifact variant for the mode, marshal noisy
/// weights for hw paths, run, argmax.
fn run_performer_batch(
    shared: &Shared,
    mode: ModeLane,
    batch: &Batch,
) -> Result<(Vec<ResponseBody>, f64)> {
    let bundle = shared
        .bundle
        .as_ref()
        .ok_or_else(|| Error::Coordinator("no trained model in artifacts".into()))?;
    let mode = mode.mode();
    let n = batch.requests.len();
    let seq_len = bundle.seq_len;

    // serve the manifest's primary task (other tasks are evaluated via
    // the experiment harness, not the serving engine)
    let task = shared
        .registry
        .manifest
        .get("task")
        .and_then(|v| v.as_str())
        .unwrap_or("pattern")
        .to_string();
    let spec = shared
        .registry
        .best_batch("performer", n, |s| {
            s.meta.get("mode").and_then(|m| m.as_str()) == Some(mode.as_str())
                && s.meta.get("task").and_then(|t| t.as_str()) == Some(task.as_str())
        })
        .ok_or_else(|| Error::Artifact(format!("no performer artifact for {mode:?}")))?;
    let b = spec.batch();

    let mut tokens = vec![0i32; b * seq_len];
    for (i, req) in batch.requests.iter().enumerate() {
        match &req.body {
            RequestBody::Performer { tokens: t, .. } => {
                if t.len() != seq_len {
                    return Err(Error::Shape(format!(
                        "performer request has {} tokens, model expects {seq_len}",
                        t.len()
                    )));
                }
                tokens[i * seq_len..(i + 1) * seq_len].copy_from_slice(t);
            }
            _ => return Err(Error::Coordinator("mixed lane".into())),
        }
    }
    // pad with copies of the first row (keeps token ids in-vocab)
    for i in n..b {
        let (head, tail) = tokens.split_at_mut(i * seq_len);
        tail[..seq_len].copy_from_slice(&head[..seq_len]);
    }

    let seed = shared.seed_ctr.fetch_add(1, Ordering::Relaxed);
    let (omega_override, param_override) = match mode {
        PerfMode::Fp32 => (None, None),
        PerfMode::HwAttn => (shared.noisy_omega.as_ref(), None),
        PerfMode::HwFull => (shared.noisy_omega.as_ref(), Some(&shared.noisy_params)),
    };
    let inputs = bundle.performer_inputs(spec, &tokens, seed, omega_override, param_override)?;
    let exe = shared.registry.load(&spec.name)?;
    let logits = exe.run_mat(&inputs, b, shared.classes)?;

    // modelled analog energy: the FAVOR+ mapping (hw modes) runs on-chip
    let energy_uj = if mode == PerfMode::Fp32 {
        0.0
    } else {
        let (d_head, m) = (bundle.omega.rows, bundle.omega.cols);
        let layers = shared
            .registry
            .model_config()
            .and_then(|c| c.get("n_layers"))
            .and_then(|v| v.as_usize())
            .unwrap_or(2);
        let heads = shared
            .registry
            .model_config()
            .and_then(|c| c.get("n_heads"))
            .and_then(|v| v.as_usize())
            .unwrap_or(2);
        // Q and K mappings, per layer, per head
        let ops = 2.0 * layers as f64 * heads as f64 * mapping_ops(n * seq_len, d_head, m);
        let (_, e_mj) = latency_energy(ops, &Device::Aimc.spec());
        e_mj * 1e3
    };

    let bodies = (0..n)
        .map(|i| {
            let row = logits.row(i);
            let mut best = 0;
            for j in 1..row.len() {
                if row[j] > row[best] {
                    best = j;
                }
            }
            ResponseBody::Class { label: best, logits: row.to_vec() }
        })
        .collect();
    Ok((bodies, energy_uj))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::PathKind;

    fn config() -> Config {
        let mut cfg = Config::default();
        cfg.artifacts_dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts")
            .to_string_lossy()
            .to_string();
        cfg.serve.max_wait_us = 500;
        cfg.serve.workers = 2;
        cfg.serve.warm = false; // tests compile lazily to stay fast
        // these tests assert per-path behavior (analog energy > 0 on
        // single-request batches); pin the dispatcher out of auto so it
        // cannot reroute the tiny analog batches digitally
        cfg.dispatch.force = "analog".to_string();
        cfg
    }

    /// Boot against the checked-in `artifacts-mini` bundle: an arccos0
    /// lane manifest with no compiled XLA programs and no trained model,
    /// so everything here runs in a bare checkout.
    fn mini_config() -> Config {
        let mut cfg = Config::default();
        cfg.artifacts_dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts-mini")
            .to_string_lossy()
            .to_string();
        cfg.serve.max_wait_us = 500;
        cfg.serve.workers = 2;
        cfg.serve.warm = false;
        cfg
    }

    fn have_artifacts() -> bool {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json")
            .exists()
    }

    #[test]
    fn engine_serves_feature_requests_both_paths() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let engine = Engine::start(&config()).unwrap();
        let sub = engine.submitter();
        let mut rng = Rng::new(0);
        for path in [PathKind::Digital, PathKind::Analog] {
            let x: Vec<f32> = (0..16).map(|_| rng.gaussian_f32()).collect();
            let resp = sub
                .call(RequestBody::Features { kernel: Kernel::Rbf, path, x })
                .unwrap();
            let body = resp.result.unwrap();
            match body {
                ResponseBody::Features(z) => {
                    assert_eq!(z.len(), 512);
                    assert!(z.iter().all(|v| v.is_finite()));
                }
                _ => panic!("wrong body"),
            }
            if path == PathKind::Analog {
                assert!(resp.energy_uj > 0.0);
            }
        }
        assert!(engine.telemetry().total_requests() >= 2);
        engine.shutdown();
    }

    #[test]
    fn analog_and_digital_features_agree_statistically() {
        if !have_artifacts() {
            return;
        }
        let engine = Engine::start(&config()).unwrap();
        let sub = engine.submitter();
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..16).map(|_| rng.gaussian_f32()).collect();
        let get = |path| {
            let resp = sub
                .call(RequestBody::Features { kernel: Kernel::Rbf, path, x: x.clone() })
                .unwrap();
            match resp.result.unwrap() {
                ResponseBody::Features(z) => z,
                _ => panic!(),
            }
        };
        let zd = get(PathKind::Digital);
        let za = get(PathKind::Analog);
        let rel = crate::util::stats::rel_fro_error(&za, &zd);
        assert!(rel > 0.0 && rel < 0.5, "analog-vs-digital rel {rel}");
        engine.shutdown();
    }

    #[test]
    fn engine_serves_performer_all_modes() {
        if !have_artifacts() {
            return;
        }
        let engine = Engine::start(&config()).unwrap();
        assert!(engine.has_model());
        let sub = engine.submitter();
        let seq_len = engine.seq_len().unwrap();
        let mut rng = Rng::new(2);
        let batch = crate::datasets::lra::gen_pattern(&mut rng, 8, seq_len);
        // HwFull is exercised by the table1 experiment test + benches; its
        // artifact compile (~30s) is too heavy for this unit test
        for mode in [PerfMode::Fp32, PerfMode::HwAttn] {
            let mut correct = 0;
            let rxs: Vec<_> = (0..8)
                .map(|i| {
                    sub.submit(RequestBody::Performer {
                        mode,
                        tokens: batch.row(i).to_vec(),
                    })
                    .unwrap()
                })
                .collect();
            for (i, rx) in rxs.into_iter().enumerate() {
                let resp = rx.recv().unwrap();
                match resp.result.unwrap() {
                    ResponseBody::Class { label, logits } => {
                        assert_eq!(logits.len(), 2);
                        if label == batch.labels[i] {
                            correct += 1;
                        }
                    }
                    _ => panic!(),
                }
            }
            // trained to ~100%; noise paths must stay near
            assert!(correct >= 6, "{mode:?}: {correct}/8");
        }
        engine.shutdown();
    }

    #[test]
    fn digital_path_serves_without_xla_artifacts() {
        let engine = Engine::start(&mini_config()).unwrap();
        let sub = engine.submitter();
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..16).map(|_| rng.gaussian_f32()).collect();
        let resp = sub
            .call(RequestBody::Features { kernel: Kernel::ArcCos0, path: PathKind::Digital, x })
            .unwrap();
        assert_eq!(resp.energy_uj, 0.0);
        match resp.result.unwrap() {
            ResponseBody::Features(z) => {
                assert_eq!(z.len(), 64);
                assert!(z.iter().all(|v| v.is_finite()));
            }
            _ => panic!("wrong body"),
        }
        engine.shutdown();
    }

    #[test]
    fn auto_dispatch_routes_small_analog_batches_digital() {
        // a lone analog request is far below the crossover the default
        // priors imply, so under force="auto" (the default) the model
        // runs it digitally: no chip MVM, so no modelled analog energy
        let mut cfg = mini_config();
        cfg.dispatch.force = "auto".to_string();
        let engine = Engine::start(&cfg).unwrap();
        let sub = engine.submitter();
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..16).map(|_| rng.gaussian_f32()).collect();
        let resp = sub
            .call(RequestBody::Features {
                kernel: Kernel::ArcCos0,
                path: PathKind::Analog,
                x: x.clone(),
            })
            .unwrap();
        assert!(resp.result.is_ok());
        assert_eq!(resp.energy_uj, 0.0, "small analog batch should route digital");
        engine.shutdown();

        // forcing analog on the same deployment pays chip energy again,
        // proving the contrast above came from the dispatcher
        let mut cfg = mini_config();
        cfg.dispatch.force = "analog".to_string();
        let engine = Engine::start(&cfg).unwrap();
        let sub = engine.submitter();
        let resp = sub
            .call(RequestBody::Features { kernel: Kernel::ArcCos0, path: PathKind::Analog, x })
            .unwrap();
        assert!(resp.result.is_ok());
        assert!(resp.energy_uj > 0.0);
        engine.shutdown();
    }

    #[test]
    fn forced_analog_and_digital_substrates_agree_statistically() {
        let mut za = Vec::new();
        let mut zd = Vec::new();
        for (force, out) in [("analog", &mut za), ("digital", &mut zd)] {
            let mut cfg = mini_config();
            cfg.dispatch.force = force.to_string();
            let engine = Engine::start(&cfg).unwrap();
            let sub = engine.submitter();
            let mut rng = Rng::new(5);
            for _ in 0..16 {
                let x: Vec<f32> = (0..16).map(|_| rng.gaussian_f32()).collect();
                let resp = sub
                    .call(RequestBody::Features {
                        kernel: Kernel::ArcCos0,
                        path: PathKind::Analog,
                        x,
                    })
                    .unwrap();
                match resp.result.unwrap() {
                    ResponseBody::Features(z) => out.extend(z),
                    _ => panic!("wrong body"),
                }
            }
            engine.shutdown();
        }
        // identical input stream and Ω twin across both boots: only
        // programming noise + drift separates the substrates (the same
        // envelope the artifact-gated agreement test uses)
        let rel = crate::util::stats::rel_fro_error(&za, &zd);
        assert!(rel > 0.0 && rel < 0.5, "analog-vs-digital rel {rel}");
    }

    #[test]
    fn invalid_dim_is_per_request_error() {
        if !have_artifacts() {
            return;
        }
        let engine = Engine::start(&config()).unwrap();
        let sub = engine.submitter();
        let resp = sub
            .call(RequestBody::Features {
                kernel: Kernel::Rbf,
                path: PathKind::Digital,
                x: vec![0.0; 3], // wrong d
            })
            .unwrap();
        assert!(resp.result.is_err());
        engine.shutdown();
    }
}
