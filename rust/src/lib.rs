//! # imka — In-Memory Kernel Approximation
//!
//! Reproduction of *"Kernel Approximation using Analog In-Memory Computing"*
//! (Büchel, Camposampiero et al., 2024) as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! - **Layer 1 (Pallas, build time)** — fused random-feature projection
//!   kernels (RFF / ArcCos0 / FAVOR+ softmax features) in
//!   `python/compile/kernels/`, validated against pure-`jnp` oracles.
//! - **Layer 2 (JAX, build time)** — Performer encoder and kernel-ridge
//!   feature pipelines in `python/compile/model.py`, AOT-lowered to HLO text
//!   artifacts consumed by the Rust runtime.
//! - **Layer 3 (Rust, request path)** — this crate: a serving coordinator
//!   (dynamic batcher, analog/digital router, tile pool) on top of a
//!   simulated IBM HERMES-class PCM AIMC chip ([`aimc`]) and a PJRT runtime
//!   ([`runtime`]) that executes the AOT artifacts. Python never runs on the
//!   request path.
//!
//! The paper's hardware (the IBM HERMES Project Chip) is not available, so
//! [`aimc`] implements a behavioural simulator of it: 64 cores of 256×256
//! PCM crossbars with differential unit cells, INT8 pulse-width DACs,
//! current-controlled-oscillator ADCs with saturation, programming noise,
//! read noise, and conductance drift. See `DESIGN.md` §Substitutions.

pub mod aimc;
pub mod attention;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod datasets;
pub mod energy;
pub mod error;
pub mod experiments;
pub mod features;
pub mod fleet;
pub mod kernels;
pub mod linalg;
pub mod npy;
pub mod obsv;
pub mod ridge;
pub mod runtime;
pub mod testkit;
pub mod util;
pub mod wire;
pub mod ziparc;

pub use error::{Error, Result};
