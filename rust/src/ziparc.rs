//! Minimal ZIP archive reader/writer (offline substitute for the `zip`
//! crate — DESIGN.md §Toolchain substitutions). `npy.rs` aliases this
//! module as `zip`, so the real crate can be swapped back in there.
//!
//! Scope: exactly what `.npz` interchange needs — STORED (method 0)
//! entries with CRC-32 validation, central-directory-driven reads, and a
//! buffered writer that emits correct local headers without seeking.
//! DEFLATE entries (`np.savez_compressed`) are rejected with a clear
//! error; the Python build path writes uncompressed `np.savez` bundles.

use std::io::{Read, Seek, SeekFrom, Write};

/// Mirror of `zip::result::ZipError` (message-only).
#[derive(Debug)]
pub struct ZipError(pub String);

impl std::fmt::Display for ZipError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for ZipError {}

fn err<T>(msg: impl Into<String>) -> Result<T, ZipError> {
    Err(ZipError(msg.into()))
}

/// Compression methods this stub understands.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompressionMethod {
    Stored,
}

/// Writer-side options, mirroring `zip::write::FileOptions`.
pub mod write {
    use super::CompressionMethod;

    #[derive(Clone, Copy, Debug)]
    pub struct FileOptions {
        pub(super) method: CompressionMethod,
    }

    impl Default for FileOptions {
        fn default() -> Self {
            FileOptions { method: CompressionMethod::Stored }
        }
    }

    impl FileOptions {
        pub fn compression_method(mut self, method: CompressionMethod) -> Self {
            self.method = method;
            self
        }
    }
}

/// CRC-32 (IEEE, reflected) — bitwise, no table; archives are small.
fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

fn u16le(b: &[u8]) -> u16 {
    u16::from_le_bytes([b[0], b[1]])
}

fn u32le(b: &[u8]) -> u32 {
    u32::from_le_bytes([b[0], b[1], b[2], b[3]])
}

struct CentralEntry {
    name: String,
    method: u16,
    crc: u32,
    comp_size: u64,
    local_offset: u64,
}

/// Read side: index the central directory, extract entries by index.
pub struct ZipArchive<R: Read + Seek> {
    reader: R,
    entries: Vec<CentralEntry>,
}

impl<R: Read + Seek> ZipArchive<R> {
    pub fn new(mut reader: R) -> Result<ZipArchive<R>, ZipError> {
        // locate the end-of-central-directory record: scan the last 64 KiB
        // + 22 bytes backward for PK\x05\x06
        let file_len = reader
            .seek(SeekFrom::End(0))
            .map_err(|e| ZipError(format!("seek: {e}")))?;
        let tail_len = file_len.min(64 * 1024 + 22);
        reader
            .seek(SeekFrom::Start(file_len - tail_len))
            .map_err(|e| ZipError(format!("seek: {e}")))?;
        let mut tail = vec![0u8; tail_len as usize];
        reader
            .read_exact(&mut tail)
            .map_err(|e| ZipError(format!("read eocd: {e}")))?;
        let eocd = match (0..tail.len().saturating_sub(21))
            .rev()
            .find(|&i| &tail[i..i + 4] == b"PK\x05\x06")
        {
            Some(i) => &tail[i..],
            None => return err("not a zip archive (no end-of-central-directory)"),
        };
        let n_total = u16le(&eocd[10..12]) as usize;
        let cd_offset = u32le(&eocd[16..20]) as u64;
        if cd_offset == 0xFFFF_FFFF || n_total == 0xFFFF {
            return err("zip64 archives unsupported");
        }

        reader
            .seek(SeekFrom::Start(cd_offset))
            .map_err(|e| ZipError(format!("seek central dir: {e}")))?;
        let mut entries = Vec::with_capacity(n_total);
        let mut hdr = [0u8; 46];
        for _ in 0..n_total {
            reader
                .read_exact(&mut hdr)
                .map_err(|e| ZipError(format!("central header: {e}")))?;
            if &hdr[..4] != b"PK\x01\x02" {
                return err("bad central directory signature");
            }
            let method = u16le(&hdr[10..12]);
            let crc = u32le(&hdr[16..20]);
            let comp_size = u32le(&hdr[20..24]) as u64;
            let name_len = u16le(&hdr[28..30]) as usize;
            let extra_len = u16le(&hdr[30..32]) as usize;
            let comment_len = u16le(&hdr[32..34]) as usize;
            let local_offset = u32le(&hdr[42..46]) as u64;
            let mut name = vec![0u8; name_len];
            reader
                .read_exact(&mut name)
                .map_err(|e| ZipError(format!("entry name: {e}")))?;
            reader
                .seek(SeekFrom::Current((extra_len + comment_len) as i64))
                .map_err(|e| ZipError(format!("seek: {e}")))?;
            entries.push(CentralEntry {
                name: String::from_utf8_lossy(&name).into_owned(),
                method,
                crc,
                comp_size,
                local_offset,
            });
        }
        Ok(ZipArchive { reader, entries })
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Extract entry `i` fully into memory (entries are small `.npy`
    /// blobs) and return a readable handle.
    pub fn by_index(&mut self, i: usize) -> Result<ZipEntry, ZipError> {
        let e = match self.entries.get(i) {
            Some(e) => e,
            None => return err(format!("entry index {i} out of range")),
        };
        if e.method != 0 {
            return err(format!(
                "entry '{}' uses compression method {} \
                 (only STORED is supported; write npz uncompressed)",
                e.name, e.method
            ));
        }
        self.reader
            .seek(SeekFrom::Start(e.local_offset))
            .map_err(|x| ZipError(format!("seek local header: {x}")))?;
        let mut hdr = [0u8; 30];
        self.reader
            .read_exact(&mut hdr)
            .map_err(|x| ZipError(format!("local header: {x}")))?;
        if &hdr[..4] != b"PK\x03\x04" {
            return err("bad local header signature");
        }
        let name_len = u16le(&hdr[26..28]) as i64;
        let extra_len = u16le(&hdr[28..30]) as i64;
        self.reader
            .seek(SeekFrom::Current(name_len + extra_len))
            .map_err(|x| ZipError(format!("seek: {x}")))?;
        let mut data = vec![0u8; e.comp_size as usize];
        self.reader
            .read_exact(&mut data)
            .map_err(|x| ZipError(format!("entry body: {x}")))?;
        if crc32(&data) != e.crc {
            return err(format!("entry '{}': CRC mismatch", e.name));
        }
        Ok(ZipEntry { name: e.name.clone(), data, pos: 0 })
    }
}

/// One extracted entry (fully buffered).
pub struct ZipEntry {
    name: String,
    data: Vec<u8>,
    pos: usize,
}

impl ZipEntry {
    pub fn name(&self) -> &str {
        &self.name
    }
}

impl Read for ZipEntry {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = buf.len().min(self.data.len() - self.pos);
        buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

struct PendingEntry {
    name: String,
    data: Vec<u8>,
}

/// Write side: buffers each entry so headers carry correct sizes without
/// seeking in the underlying writer.
pub struct ZipWriter<W: Write> {
    out: W,
    pending: Option<PendingEntry>,
    /// (name, crc, size, local_offset)
    written: Vec<(String, u32, u32, u32)>,
    offset: u32,
}

impl<W: Write> ZipWriter<W> {
    pub fn new(out: W) -> ZipWriter<W> {
        ZipWriter { out, pending: None, written: Vec::new(), offset: 0 }
    }

    pub fn start_file<S: Into<String>>(
        &mut self,
        name: S,
        _opts: write::FileOptions,
    ) -> Result<(), ZipError> {
        self.flush_pending().map_err(|e| ZipError(format!("zip write: {e}")))?;
        self.pending = Some(PendingEntry { name: name.into(), data: Vec::new() });
        Ok(())
    }

    fn flush_pending(&mut self) -> std::io::Result<()> {
        let Some(entry) = self.pending.take() else {
            return Ok(());
        };
        // no zip64: sizes and offsets must fit the classic 32-bit fields
        if entry.data.len() > u32::MAX as usize {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!("zip entry '{}' exceeds 4 GiB (zip64 unsupported)", entry.name),
            ));
        }
        let crc = crc32(&entry.data);
        let size = entry.data.len() as u32;
        let name = entry.name.as_bytes();
        let mut hdr = Vec::with_capacity(30 + name.len());
        hdr.extend_from_slice(b"PK\x03\x04");
        hdr.extend_from_slice(&20u16.to_le_bytes()); // version needed
        hdr.extend_from_slice(&0u16.to_le_bytes()); // flags
        hdr.extend_from_slice(&0u16.to_le_bytes()); // method: stored
        hdr.extend_from_slice(&0u16.to_le_bytes()); // mod time
        hdr.extend_from_slice(&0u16.to_le_bytes()); // mod date
        hdr.extend_from_slice(&crc.to_le_bytes());
        hdr.extend_from_slice(&size.to_le_bytes()); // compressed
        hdr.extend_from_slice(&size.to_le_bytes()); // uncompressed
        hdr.extend_from_slice(&(name.len() as u16).to_le_bytes());
        hdr.extend_from_slice(&0u16.to_le_bytes()); // extra len
        hdr.extend_from_slice(name);
        self.out.write_all(&hdr)?;
        self.out.write_all(&entry.data)?;
        self.written.push((entry.name, crc, size, self.offset));
        self.offset = (hdr.len() as u32)
            .checked_add(size)
            .and_then(|n| self.offset.checked_add(n))
            .ok_or_else(|| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidInput,
                    "zip archive exceeds 4 GiB (zip64 unsupported)",
                )
            })?;
        Ok(())
    }

    /// Write the central directory + end record; returns the inner writer.
    pub fn finish(mut self) -> Result<W, ZipError> {
        self.flush_pending().map_err(|e| ZipError(format!("zip write: {e}")))?;
        let cd_offset = self.offset;
        let mut cd_size = 0u32;
        for (name, crc, size, local_offset) in &self.written {
            let name = name.as_bytes();
            let mut hdr = Vec::with_capacity(46 + name.len());
            hdr.extend_from_slice(b"PK\x01\x02");
            hdr.extend_from_slice(&20u16.to_le_bytes()); // version made by
            hdr.extend_from_slice(&20u16.to_le_bytes()); // version needed
            hdr.extend_from_slice(&0u16.to_le_bytes()); // flags
            hdr.extend_from_slice(&0u16.to_le_bytes()); // method: stored
            hdr.extend_from_slice(&0u16.to_le_bytes()); // mod time
            hdr.extend_from_slice(&0u16.to_le_bytes()); // mod date
            hdr.extend_from_slice(&crc.to_le_bytes());
            hdr.extend_from_slice(&size.to_le_bytes());
            hdr.extend_from_slice(&size.to_le_bytes());
            hdr.extend_from_slice(&(name.len() as u16).to_le_bytes());
            hdr.extend_from_slice(&0u16.to_le_bytes()); // extra len
            hdr.extend_from_slice(&0u16.to_le_bytes()); // comment len
            hdr.extend_from_slice(&0u16.to_le_bytes()); // disk number
            hdr.extend_from_slice(&0u16.to_le_bytes()); // internal attrs
            hdr.extend_from_slice(&0u32.to_le_bytes()); // external attrs
            hdr.extend_from_slice(&local_offset.to_le_bytes());
            hdr.extend_from_slice(name);
            self.out
                .write_all(&hdr)
                .map_err(|e| ZipError(format!("zip central dir: {e}")))?;
            cd_size += hdr.len() as u32;
        }
        let n = self.written.len() as u16;
        let mut eocd = Vec::with_capacity(22);
        eocd.extend_from_slice(b"PK\x05\x06");
        eocd.extend_from_slice(&0u16.to_le_bytes()); // this disk
        eocd.extend_from_slice(&0u16.to_le_bytes()); // cd disk
        eocd.extend_from_slice(&n.to_le_bytes()); // entries this disk
        eocd.extend_from_slice(&n.to_le_bytes()); // entries total
        eocd.extend_from_slice(&cd_size.to_le_bytes());
        eocd.extend_from_slice(&cd_offset.to_le_bytes());
        eocd.extend_from_slice(&0u16.to_le_bytes()); // comment len
        self.out
            .write_all(&eocd)
            .map_err(|e| ZipError(format!("zip eocd: {e}")))?;
        self.out
            .flush()
            .map_err(|e| ZipError(format!("zip flush: {e}")))?;
        Ok(self.out)
    }
}

impl<W: Write> Write for ZipWriter<W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match &mut self.pending {
            Some(e) => {
                e.data.extend_from_slice(buf);
                Ok(buf.len())
            }
            None => Err(std::io::Error::new(
                std::io::ErrorKind::Other,
                "zip: write before start_file",
            )),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn roundtrip_two_entries() {
        let mut w = ZipWriter::new(Cursor::new(Vec::new()));
        w.start_file("a.bin", write::FileOptions::default()).unwrap();
        w.write_all(b"hello zip").unwrap();
        w.start_file("dir/b.bin", write::FileOptions::default()).unwrap();
        w.write_all(&[0u8, 1, 2, 255]).unwrap();
        let bytes = w.finish().unwrap().into_inner();

        let mut arc = ZipArchive::new(Cursor::new(bytes)).unwrap();
        assert_eq!(arc.len(), 2);
        let mut names = Vec::new();
        for i in 0..arc.len() {
            let mut e = arc.by_index(i).unwrap();
            names.push(e.name().to_string());
            let mut buf = Vec::new();
            e.read_to_end(&mut buf).unwrap();
            if e.name() == "a.bin" {
                assert_eq!(buf, b"hello zip");
            } else {
                assert_eq!(buf, vec![0u8, 1, 2, 255]);
            }
        }
        names.sort();
        assert_eq!(names, vec!["a.bin", "dir/b.bin"]);
    }

    #[test]
    fn empty_archive_roundtrips() {
        let bytes = ZipWriter::new(Cursor::new(Vec::new()))
            .finish()
            .unwrap()
            .into_inner();
        let arc = ZipArchive::new(Cursor::new(bytes)).unwrap();
        assert!(arc.is_empty());
    }

    #[test]
    fn garbage_rejected() {
        assert!(ZipArchive::new(Cursor::new(b"not a zip".to_vec())).is_err());
        assert!(ZipArchive::new(Cursor::new(Vec::new())).is_err());
    }

    #[test]
    fn crc32_known_vector() {
        // CRC-32 of "123456789" is 0xCBF43926 (IEEE test vector)
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn corruption_detected() {
        let mut w = ZipWriter::new(Cursor::new(Vec::new()));
        w.start_file("x", write::FileOptions::default()).unwrap();
        w.write_all(b"payload-payload").unwrap();
        let mut bytes = w.finish().unwrap().into_inner();
        // flip a body byte (local header is 30 + 1 name byte)
        bytes[33] ^= 0xFF;
        let mut arc = ZipArchive::new(Cursor::new(bytes)).unwrap();
        let err = arc.by_index(0).unwrap_err();
        assert!(err.to_string().contains("CRC"));
    }
}
