//! Minimal blocking binary-frame client (tests + benches), the frame
//! counterpart of [`crate::coordinator::Client`].

use std::io::{Read, Write};
use std::net::TcpStream;

use super::frame::{WireReply, WireRequest, MAGIC_REPLY, PREFIX_LEN};
use crate::error::{Error, Result};

/// Guard against a corrupt reply length turning into an absurd
/// allocation client-side.
const MAX_REPLY_BODY: usize = 256 * 1024 * 1024;

pub struct BinaryClient {
    stream: TcpStream,
}

impl BinaryClient {
    pub fn connect(addr: &std::net::SocketAddr) -> Result<BinaryClient> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| Error::Coordinator(format!("connect {addr}: {e}")))?;
        stream.set_nodelay(true).ok();
        Ok(BinaryClient { stream })
    }

    /// Send one frame, block for its reply.
    pub fn call(&mut self, request: &WireRequest) -> Result<WireReply> {
        self.stream.write_all(&request.encode())?;
        self.read_reply()
    }

    /// Read one reply frame off the stream (for pipelined use: send
    /// several frames with [`send`], then drain replies in order).
    ///
    /// [`send`]: BinaryClient::send
    pub fn read_reply(&mut self) -> Result<WireReply> {
        let mut prefix = [0u8; PREFIX_LEN];
        self.stream.read_exact(&mut prefix)?;
        if prefix[0] != MAGIC_REPLY {
            return Err(Error::Parse(format!("bad reply magic 0x{:02x}", prefix[0])));
        }
        let len = u32::from_le_bytes(prefix[4..8].try_into().unwrap()) as usize;
        if len > MAX_REPLY_BODY {
            return Err(Error::Parse(format!("reply body of {len} bytes is implausible")));
        }
        let mut body = vec![0u8; len];
        self.stream.read_exact(&mut body)?;
        WireReply::decode_body(prefix[1], prefix[2], &body)
    }

    /// Fire a frame without waiting for the reply (pipelining).
    pub fn send(&mut self, request: &WireRequest) -> Result<()> {
        self.stream.write_all(&request.encode())?;
        Ok(())
    }
}
