//! Length-prefixed binary frames (the data-plane fast path).
//!
//! Every frame is an 8-byte prefix plus a body; all integers and floats
//! are little-endian (byte-by-byte layout in `docs/protocol.md`):
//!
//! ```text
//! request:  [ 0xB1 | verb u8 | flags u16 (0) | body_len u32 ] body
//! reply:    [ 0xB2 | verb u8 | status u8     | 0u8 | body_len u32 ] body
//! ```
//!
//! Bodies always start with a `u64` request id: on requests it is a
//! client-chosen correlation id (0 = none), echoed verbatim on error
//! replies; successful data-plane replies carry the engine-assigned id
//! instead, exactly like the JSON encoding's `request_id` field.
//!
//! Tensor payloads (`q`/`k`/`v`, feature inputs, performer tokens and
//! every reply vector) are raw `f32`/`i32` runs: the decoder turns them
//! into batch-ready buffers in one `chunks_exact(4)` pass — no
//! per-number text parsing, no intermediate `Json` tree — and those
//! buffers then *move* through `RequestBody` → batcher → engine without
//! another copy. Request-side floats must be finite; a NaN/Inf payload
//! is a typed error, not a poisoned session.

use crate::coordinator::request::{PathKind, PerfMode};
use crate::error::{Error, Result};
use crate::kernels::Kernel;

/// First byte of a binary request frame. JSON text can never start with
/// this byte (it is not valid leading UTF-8 for any JSON value), which
/// is what makes per-request auto-detection unambiguous.
pub const MAGIC_REQUEST: u8 = 0xB1;
/// First byte of a binary reply frame.
pub const MAGIC_REPLY: u8 = 0xB2;
/// Fixed prefix length, both directions.
pub const PREFIX_LEN: usize = 8;

/// Verb tags (requests and reply echoes).
pub mod verb {
    pub const PING: u8 = 0x01;
    pub const ATTN_APPEND: u8 = 0x10;
    pub const FEATURES: u8 = 0x11;
    pub const PERFORMER: u8 = 0x12;
    pub const ATTN_OPEN: u8 = 0x13;
    pub const ATTN_CLOSE: u8 = 0x14;
}

fn kernel_tag(k: Kernel) -> u8 {
    match k {
        Kernel::Rbf => 0,
        Kernel::ArcCos0 => 1,
        Kernel::Softmax => 2,
    }
}

fn kernel_from_tag(t: u8) -> Result<Kernel> {
    match t {
        0 => Ok(Kernel::Rbf),
        1 => Ok(Kernel::ArcCos0),
        2 => Ok(Kernel::Softmax),
        other => Err(Error::Parse(format!("unknown kernel tag 0x{other:02x}"))),
    }
}

/// `attn_open` "use the configured default path" tag.
const PATH_DEFAULT: u8 = 0xFF;

/// A decoded binary request — the frame-level mirror of the JSON verbs
/// that carry tensor payloads (control verbs stay JSON-only).
#[derive(Clone, Debug, PartialEq)]
pub enum WireRequest {
    Ping { request_id: u64 },
    AttnOpen { request_id: u64, path: Option<PathKind> },
    AttnAppend { request_id: u64, session: u64, q: Vec<f32>, k: Vec<f32>, v: Vec<f32> },
    AttnClose { request_id: u64, session: u64 },
    Features { request_id: u64, kernel: Kernel, path: PathKind, x: Vec<f32> },
    Performer { request_id: u64, mode: PerfMode, tokens: Vec<i32> },
}

impl WireRequest {
    pub fn verb(&self) -> u8 {
        match self {
            WireRequest::Ping { .. } => verb::PING,
            WireRequest::AttnOpen { .. } => verb::ATTN_OPEN,
            WireRequest::AttnAppend { .. } => verb::ATTN_APPEND,
            WireRequest::AttnClose { .. } => verb::ATTN_CLOSE,
            WireRequest::Features { .. } => verb::FEATURES,
            WireRequest::Performer { .. } => verb::PERFORMER,
        }
    }

    pub fn request_id(&self) -> u64 {
        match self {
            WireRequest::Ping { request_id }
            | WireRequest::AttnOpen { request_id, .. }
            | WireRequest::AttnAppend { request_id, .. }
            | WireRequest::AttnClose { request_id, .. }
            | WireRequest::Features { request_id, .. }
            | WireRequest::Performer { request_id, .. } => *request_id,
        }
    }

    /// Encode the full frame (prefix + body) — the client side.
    pub fn encode(&self) -> Vec<u8> {
        let mut body = Vec::new();
        put_u64(&mut body, self.request_id());
        match self {
            WireRequest::Ping { .. } => {}
            WireRequest::AttnOpen { path, .. } => {
                body.push(path.map(path_tag).unwrap_or(PATH_DEFAULT));
            }
            WireRequest::AttnAppend { session, q, k, v, .. } => {
                put_u64(&mut body, *session);
                put_u32(&mut body, q.len() as u32);
                put_f32s(&mut body, q);
                put_f32s(&mut body, k);
                put_f32s(&mut body, v);
            }
            WireRequest::AttnClose { session, .. } => put_u64(&mut body, *session),
            WireRequest::Features { kernel, path, x, .. } => {
                body.push(kernel_tag(*kernel));
                body.push(path_tag(*path));
                body.extend_from_slice(&[0, 0]); // reserved
                put_u32(&mut body, x.len() as u32);
                put_f32s(&mut body, x);
            }
            WireRequest::Performer { mode, tokens, .. } => {
                body.push(mode.wire_tag());
                body.extend_from_slice(&[0, 0, 0]); // reserved
                put_u32(&mut body, tokens.len() as u32);
                for t in tokens {
                    body.extend_from_slice(&t.to_le_bytes());
                }
            }
        }
        let mut frame = Vec::with_capacity(PREFIX_LEN + body.len());
        frame.push(MAGIC_REQUEST);
        frame.push(self.verb());
        frame.extend_from_slice(&0u16.to_le_bytes()); // flags (reserved)
        frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
        frame.extend_from_slice(&body);
        frame
    }

    /// Decode a request body (the prefix was already consumed and
    /// validated by the server's framing loop).
    pub fn decode_body(verb_tag: u8, body: &[u8]) -> Result<WireRequest> {
        let mut cur = Cur::new(body);
        let request_id = cur.u64()?;
        let req = match verb_tag {
            verb::PING => WireRequest::Ping { request_id },
            verb::ATTN_OPEN => {
                let tag = cur.u8()?;
                let path = if tag == PATH_DEFAULT { None } else { Some(path_from_tag(tag)?) };
                WireRequest::AttnOpen { request_id, path }
            }
            verb::ATTN_APPEND => {
                let session = cur.u64()?;
                let n = cur.u32()? as usize;
                let q = cur.f32s_finite(n, "q")?;
                let k = cur.f32s_finite(n, "k")?;
                let v = cur.f32s_finite(n, "v")?;
                WireRequest::AttnAppend { request_id, session, q, k, v }
            }
            verb::ATTN_CLOSE => WireRequest::AttnClose { request_id, session: cur.u64()? },
            verb::FEATURES => {
                let kernel = kernel_from_tag(cur.u8()?)?;
                let path = path_from_tag(cur.u8()?)?;
                cur.take(2)?; // reserved
                let n = cur.u32()? as usize;
                let x = cur.f32s_finite(n, "x")?;
                WireRequest::Features { request_id, kernel, path, x }
            }
            verb::PERFORMER => {
                let mode = PerfMode::from_wire_tag(cur.u8()?)
                    .ok_or_else(|| Error::Parse("unknown performer mode tag".into()))?;
                cur.take(3)?; // reserved
                let n = cur.u32()? as usize;
                let tokens = cur.i32s(n)?;
                WireRequest::Performer { request_id, mode, tokens }
            }
            other => {
                return Err(Error::Parse(format!("unknown wire verb 0x{other:02x}")));
            }
        };
        cur.done()?;
        Ok(req)
    }
}

fn path_tag(p: PathKind) -> u8 {
    p.wire_tag()
}

fn path_from_tag(t: u8) -> Result<PathKind> {
    PathKind::from_wire_tag(t).ok_or_else(|| Error::Parse(format!("unknown path tag 0x{t:02x}")))
}

/// A binary reply — either a typed error (verb echoed, message carried
/// as UTF-8) or the verb-specific success payload.
#[derive(Clone, Debug, PartialEq)]
pub enum WireReply {
    Err { verb: u8, request_id: u64, message: String },
    Pong { request_id: u64 },
    AttnOpened { request_id: u64, session: u64, heads: u32, d_head: u32, m: u32, path: PathKind },
    AttnClosed { request_id: u64, session: u64, tokens: u64 },
    AttnOut {
        request_id: u64,
        session: u64,
        index: u32,
        latency_us: f64,
        energy_uj: f64,
        batch: u32,
        y: Vec<f32>,
    },
    Features { request_id: u64, latency_us: f64, energy_uj: f64, batch: u32, z: Vec<f32> },
    Class {
        request_id: u64,
        latency_us: f64,
        energy_uj: f64,
        batch: u32,
        label: u32,
        logits: Vec<f32>,
    },
}

impl WireReply {
    pub fn verb(&self) -> u8 {
        match self {
            WireReply::Err { verb, .. } => *verb,
            WireReply::Pong { .. } => verb::PING,
            WireReply::AttnOpened { .. } => verb::ATTN_OPEN,
            WireReply::AttnClosed { .. } => verb::ATTN_CLOSE,
            WireReply::AttnOut { .. } => verb::ATTN_APPEND,
            WireReply::Features { .. } => verb::FEATURES,
            WireReply::Class { .. } => verb::PERFORMER,
        }
    }

    pub fn is_ok(&self) -> bool {
        !matches!(self, WireReply::Err { .. })
    }

    pub fn request_id(&self) -> u64 {
        match self {
            WireReply::Err { request_id, .. }
            | WireReply::Pong { request_id }
            | WireReply::AttnOpened { request_id, .. }
            | WireReply::AttnClosed { request_id, .. }
            | WireReply::AttnOut { request_id, .. }
            | WireReply::Features { request_id, .. }
            | WireReply::Class { request_id, .. } => *request_id,
        }
    }

    /// Encode into two reusable scratch buffers (prefix + body) so the
    /// server can issue one vectored write per reply without
    /// reallocating per request. Both buffers are cleared first.
    pub fn encode_into(&self, head: &mut Vec<u8>, body: &mut Vec<u8>) {
        head.clear();
        body.clear();
        put_u64(body, self.request_id());
        match self {
            WireReply::Err { message, .. } => {
                put_u32(body, message.len() as u32);
                body.extend_from_slice(message.as_bytes());
            }
            WireReply::Pong { .. } => {}
            WireReply::AttnOpened { session, heads, d_head, m, path, .. } => {
                put_u64(body, *session);
                put_u32(body, *heads);
                put_u32(body, *d_head);
                put_u32(body, *m);
                body.push(path.wire_tag());
            }
            WireReply::AttnClosed { session, tokens, .. } => {
                put_u64(body, *session);
                put_u64(body, *tokens);
            }
            WireReply::AttnOut { session, index, latency_us, energy_uj, batch, y, .. } => {
                put_u64(body, *session);
                put_u32(body, *index);
                put_f64(body, *latency_us);
                put_f64(body, *energy_uj);
                put_u32(body, *batch);
                put_u32(body, y.len() as u32);
                put_f32s(body, y);
            }
            WireReply::Features { latency_us, energy_uj, batch, z, .. } => {
                put_f64(body, *latency_us);
                put_f64(body, *energy_uj);
                put_u32(body, *batch);
                put_u32(body, z.len() as u32);
                put_f32s(body, z);
            }
            WireReply::Class { latency_us, energy_uj, batch, label, logits, .. } => {
                put_f64(body, *latency_us);
                put_f64(body, *energy_uj);
                put_u32(body, *batch);
                put_u32(body, *label);
                put_u32(body, logits.len() as u32);
                put_f32s(body, logits);
            }
        }
        head.push(MAGIC_REPLY);
        head.push(self.verb());
        head.push(if self.is_ok() { 1 } else { 0 });
        head.push(0); // reserved
        head.extend_from_slice(&(body.len() as u32).to_le_bytes());
    }

    /// Decode a reply body — the client side.
    pub fn decode_body(verb_tag: u8, status: u8, body: &[u8]) -> Result<WireReply> {
        let mut cur = Cur::new(body);
        let request_id = cur.u64()?;
        if status == 0 {
            let n = cur.u32()? as usize;
            let raw = cur.take(n)?;
            let message = String::from_utf8(raw.to_vec())
                .map_err(|_| Error::Parse("error message is not UTF-8".into()))?;
            cur.done()?;
            return Ok(WireReply::Err { verb: verb_tag, request_id, message });
        }
        let reply = match verb_tag {
            verb::PING => WireReply::Pong { request_id },
            verb::ATTN_OPEN => {
                let session = cur.u64()?;
                let heads = cur.u32()?;
                let d_head = cur.u32()?;
                let m = cur.u32()?;
                let path = path_from_tag(cur.u8()?)?;
                WireReply::AttnOpened { request_id, session, heads, d_head, m, path }
            }
            verb::ATTN_CLOSE => {
                WireReply::AttnClosed { request_id, session: cur.u64()?, tokens: cur.u64()? }
            }
            verb::ATTN_APPEND => {
                let session = cur.u64()?;
                let index = cur.u32()?;
                let latency_us = cur.f64()?;
                let energy_uj = cur.f64()?;
                let batch = cur.u32()?;
                let n = cur.u32()? as usize;
                let y = cur.f32s(n)?;
                WireReply::AttnOut { request_id, session, index, latency_us, energy_uj, batch, y }
            }
            verb::FEATURES => {
                let latency_us = cur.f64()?;
                let energy_uj = cur.f64()?;
                let batch = cur.u32()?;
                let n = cur.u32()? as usize;
                let z = cur.f32s(n)?;
                WireReply::Features { request_id, latency_us, energy_uj, batch, z }
            }
            verb::PERFORMER => {
                let latency_us = cur.f64()?;
                let energy_uj = cur.f64()?;
                let batch = cur.u32()?;
                let label = cur.u32()?;
                let n = cur.u32()? as usize;
                let logits = cur.f32s(n)?;
                WireReply::Class { request_id, latency_us, energy_uj, batch, label, logits }
            }
            other => {
                return Err(Error::Parse(format!("unknown wire verb 0x{other:02x}")));
            }
        };
        cur.done()?;
        Ok(reply)
    }
}

// -- little-endian buffer helpers -------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(out: &mut Vec<u8>, vs: &[f32]) {
    out.reserve(vs.len() * 4);
    for v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Bounds-checked read cursor over a frame body.
struct Cur<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Cur<'a> {
        Cur { b, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| Error::Parse("truncated frame body".into()))?;
        let out = &self.b[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// One pass over a raw f32 run, straight into a batch-ready buffer.
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(n.checked_mul(4).ok_or_else(|| Error::Parse("oversize f32 run".into()))?)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    /// `f32s` that rejects NaN/Inf with the offending field's name.
    fn f32s_finite(&mut self, n: usize, what: &str) -> Result<Vec<f32>> {
        let vs = self.f32s(n)?;
        if vs.iter().any(|v| !v.is_finite()) {
            return Err(Error::Parse(format!("{what} must contain finite numbers")));
        }
        Ok(vs)
    }

    fn i32s(&mut self, n: usize) -> Result<Vec<i32>> {
        let raw = self.take(n.checked_mul(4).ok_or_else(|| Error::Parse("oversize i32 run".into()))?)?;
        Ok(raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn done(&self) -> Result<()> {
        if self.pos != self.b.len() {
            return Err(Error::Parse(format!(
                "trailing bytes in frame body ({} of {} consumed)",
                self.pos,
                self.b.len()
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_request(req: WireRequest) {
        let frame = req.encode();
        assert_eq!(frame[0], MAGIC_REQUEST);
        assert_eq!(frame[1], req.verb());
        let len = u32::from_le_bytes(frame[4..8].try_into().unwrap()) as usize;
        assert_eq!(len, frame.len() - PREFIX_LEN);
        let back = WireRequest::decode_body(frame[1], &frame[PREFIX_LEN..]).unwrap();
        assert_eq!(back, req);
    }

    #[test]
    fn request_roundtrips_every_verb() {
        roundtrip_request(WireRequest::Ping { request_id: 7 });
        roundtrip_request(WireRequest::AttnOpen { request_id: 1, path: None });
        roundtrip_request(WireRequest::AttnOpen { request_id: 2, path: Some(PathKind::Analog) });
        roundtrip_request(WireRequest::AttnAppend {
            request_id: 3,
            session: 9,
            q: vec![0.5, -1.25],
            k: vec![1.0, 2.0],
            v: vec![-0.125, 8.0],
        });
        roundtrip_request(WireRequest::AttnClose { request_id: 4, session: 9 });
        roundtrip_request(WireRequest::Features {
            request_id: 5,
            kernel: Kernel::ArcCos0,
            path: PathKind::Digital,
            x: vec![0.0, 0.25, -3.5],
        });
        roundtrip_request(WireRequest::Performer {
            request_id: 6,
            mode: PerfMode::HwAttn,
            tokens: vec![-1, 0, 255],
        });
    }

    fn roundtrip_reply(reply: WireReply) {
        let (mut head, mut body) = (Vec::new(), Vec::new());
        reply.encode_into(&mut head, &mut body);
        assert_eq!(head.len(), PREFIX_LEN);
        assert_eq!(head[0], MAGIC_REPLY);
        assert_eq!(head[1], reply.verb());
        assert_eq!(head[2], if reply.is_ok() { 1 } else { 0 });
        assert_eq!(u32::from_le_bytes(head[4..8].try_into().unwrap()) as usize, body.len());
        let back = WireReply::decode_body(head[1], head[2], &body).unwrap();
        assert_eq!(back, reply);
    }

    #[test]
    fn reply_roundtrips_every_shape() {
        roundtrip_reply(WireReply::Err {
            verb: verb::ATTN_APPEND,
            request_id: 11,
            message: "no open attention session 3".into(),
        });
        roundtrip_reply(WireReply::Pong { request_id: 0 });
        roundtrip_reply(WireReply::AttnOpened {
            request_id: 1,
            session: 5,
            heads: 2,
            d_head: 8,
            m: 32,
            path: PathKind::Analog,
        });
        roundtrip_reply(WireReply::AttnClosed { request_id: 2, session: 5, tokens: 100 });
        roundtrip_reply(WireReply::AttnOut {
            request_id: 3,
            session: 5,
            index: 41,
            latency_us: 123.5,
            energy_uj: 0.25,
            batch: 4,
            y: vec![1.0, -2.0, 3.5],
        });
        roundtrip_reply(WireReply::Features {
            request_id: 4,
            latency_us: 10.0,
            energy_uj: 0.5,
            batch: 1,
            z: vec![0.0; 8],
        });
        roundtrip_reply(WireReply::Class {
            request_id: 5,
            latency_us: 9.0,
            energy_uj: 1.5,
            batch: 2,
            label: 1,
            logits: vec![0.1, 0.9],
        });
    }

    #[test]
    fn scratch_buffers_are_reusable_across_replies() {
        let (mut head, mut body) = (Vec::new(), Vec::new());
        WireReply::Features {
            request_id: 1,
            latency_us: 1.0,
            energy_uj: 1.0,
            batch: 1,
            z: vec![9.0; 64],
        }
        .encode_into(&mut head, &mut body);
        let big = body.len();
        WireReply::Pong { request_id: 2 }.encode_into(&mut head, &mut body);
        assert_eq!(body.len(), 8, "encode_into must clear the scratch");
        assert!(big > body.len());
        assert_eq!(WireReply::decode_body(head[1], head[2], &body).unwrap(),
            WireReply::Pong { request_id: 2 });
    }

    #[test]
    fn truncated_body_is_a_typed_error() {
        let req = WireRequest::AttnAppend {
            request_id: 1,
            session: 2,
            q: vec![1.0; 4],
            k: vec![1.0; 4],
            v: vec![1.0; 4],
        };
        let frame = req.encode();
        let body = &frame[PREFIX_LEN..];
        for cut in [0, 8, 20, body.len() - 1] {
            let err = WireRequest::decode_body(verb::ATTN_APPEND, &body[..cut]).unwrap_err();
            assert!(err.to_string().contains("truncated"), "cut {cut}: {err}");
        }
    }

    #[test]
    fn trailing_bytes_are_a_typed_error() {
        let mut frame = WireRequest::Ping { request_id: 1 }.encode();
        frame.push(0xAA);
        let err = WireRequest::decode_body(verb::PING, &frame[PREFIX_LEN..]).unwrap_err();
        assert!(err.to_string().contains("trailing"), "{err}");
    }

    #[test]
    fn non_finite_payloads_are_rejected_by_field() {
        for (field, qv, kv, vv) in [
            ("q", f32::NAN, 0.0, 0.0),
            ("k", 0.0, f32::INFINITY, 0.0),
            ("v", 0.0, 0.0, f32::NEG_INFINITY),
        ] {
            let frame = WireRequest::AttnAppend {
                request_id: 1,
                session: 1,
                q: vec![qv],
                k: vec![kv],
                v: vec![vv],
            }
            .encode();
            let err = WireRequest::decode_body(verb::ATTN_APPEND, &frame[PREFIX_LEN..]).unwrap_err();
            assert!(err.to_string().contains(field), "{err}");
            assert!(err.to_string().contains("finite"), "{err}");
        }
        let frame = WireRequest::Features {
            request_id: 1,
            kernel: Kernel::Rbf,
            path: PathKind::Digital,
            x: vec![f32::NAN],
        }
        .encode();
        let err = WireRequest::decode_body(verb::FEATURES, &frame[PREFIX_LEN..]).unwrap_err();
        assert!(err.to_string().contains('x'), "{err}");
    }

    #[test]
    fn unknown_tags_are_typed_errors() {
        let mut body = Vec::new();
        put_u64(&mut body, 0);
        let err = WireRequest::decode_body(0x7F, &body).unwrap_err();
        assert!(err.to_string().contains("unknown wire verb"), "{err}");

        let mut body = Vec::new();
        put_u64(&mut body, 0);
        body.extend_from_slice(&[9, 0, 0, 0]); // kernel tag 9
        put_u32(&mut body, 0);
        let err = WireRequest::decode_body(verb::FEATURES, &body).unwrap_err();
        assert!(err.to_string().contains("kernel tag"), "{err}");
    }
}
