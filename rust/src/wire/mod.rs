//! Wire codecs for the serving TCP listener.
//!
//! One listener speaks two encodings (see `docs/protocol.md`):
//!
//! - **newline-JSON** — the original line protocol, kept byte-for-byte
//!   compatible for every existing client and test;
//! - **binary frames** ([`frame`]) — length-prefixed frames whose tensor
//!   payloads (q/k/v, `features` x, `performer` tokens) travel as raw
//!   little-endian numbers and decode straight into the batch buffers,
//!   with no per-number text parsing and no intermediate [`Json`] tree.
//!
//! Auto-detection is per request: a request whose first byte is
//! [`frame::MAGIC_REQUEST`] (0xB1 — never the first byte of JSON text)
//! is a binary frame, any other first byte starts a JSON line. Both
//! encodings can interleave on one pipelined connection.
//!
//! [`scan`] is the third piece: a lazy path-scanner for the small JSON
//! control verbs (`ping`/`stats`/`trace`/...) that extracts only the few
//! fields dispatch needs instead of building the full tree.
//!
//! [`Json`]: crate::config::json::Json

pub mod client;
pub mod frame;
pub mod scan;

pub use client::BinaryClient;
pub use frame::{WireReply, WireRequest, MAGIC_REPLY, MAGIC_REQUEST, PREFIX_LEN};
pub use scan::scan_control_line;

use crate::config::ServeConfig;

/// Which encodings a listener accepts ( `[serve] wire` / `--wire`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireMode {
    /// per-request first-byte detection (the default)
    Auto,
    /// newline-JSON only; binary frames get a typed error + close
    Json,
    /// binary frames only; JSON lines get a typed error + close
    Binary,
}

impl WireMode {
    pub fn parse(s: &str) -> Option<WireMode> {
        match s {
            "auto" => Some(WireMode::Auto),
            "json" => Some(WireMode::Json),
            "binary" => Some(WireMode::Binary),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            WireMode::Auto => "auto",
            WireMode::Json => "json",
            WireMode::Binary => "binary",
        }
    }
}

/// Per-connection wire policy, derived from `[serve]` at engine boot.
#[derive(Clone, Debug)]
pub struct WireConfig {
    pub mode: WireMode,
    /// hard cap on one request: binary frame body bytes and JSON line
    /// bytes alike
    pub max_frame_bytes: usize,
    /// close (with a typed error) a connection that sends no complete
    /// request for this long — covers both silence and half-sent frames
    pub idle_timeout: std::time::Duration,
}

impl WireConfig {
    pub fn from_serve(cfg: &ServeConfig) -> WireConfig {
        WireConfig {
            // settings.rs validates the string at config load; an
            // unknown value here (hand-built Config) falls back to auto
            mode: WireMode::parse(&cfg.wire).unwrap_or(WireMode::Auto),
            max_frame_bytes: cfg.max_frame_bytes.max(1),
            idle_timeout: std::time::Duration::from_secs_f64(cfg.idle_timeout_s.max(0.001)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_mode_parse_roundtrip() {
        for m in [WireMode::Auto, WireMode::Json, WireMode::Binary] {
            assert_eq!(WireMode::parse(m.as_str()), Some(m));
        }
        assert_eq!(WireMode::parse("msgpack"), None);
    }

    #[test]
    fn wire_config_defaults_from_serve() {
        let cfg = ServeConfig::default();
        let w = WireConfig::from_serve(&cfg);
        assert_eq!(w.mode, WireMode::Auto);
        assert_eq!(w.max_frame_bytes, 16 * 1024 * 1024);
        assert_eq!(w.idle_timeout, std::time::Duration::from_secs(900));
    }
}
