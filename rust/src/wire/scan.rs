//! Lazy path-scanner for the JSON control verbs.
//!
//! Control requests (`ping`/`stats`/`health`/`metrics`/`trace`/`series`/
//! `alerts`/`events`/`drain`) are small objects of which dispatch reads
//! at most four fields — yet the line protocol used to build a full
//! [`Json`] tree for every one of them. This scanner walks the line
//! lexically, materializes *only* the fields dispatch can consume and
//! skips everything else without allocating, returning a minimal
//! [`Json::Obj`] that the existing dispatch consumes unchanged (so every
//! typed-error behavior — "limit must be a number", negative-index
//! rejection, `request_id` echo — is preserved bit-for-bit).
//!
//! The scanner is deliberately conservative: anything it is not sure
//! about — a non-control `type`, a needed field holding a nested value,
//! an escape in a key, trailing bytes — returns `None` and the caller
//! falls back to the full parser, whose error messages existing clients
//! and tests pin.
//!
//! [`Json`]: crate::config::json::Json
//! [`Json::Obj`]: crate::config::json::Json::Obj

use std::collections::BTreeMap;

use crate::config::json::Json;

/// Verbs the scanner handles; everything else falls back to `Json::parse`.
const CONTROL_VERBS: [&str; 9] =
    ["ping", "stats", "health", "metrics", "trace", "series", "alerts", "events", "drain"];

/// The only fields control dispatch ever reads (plus `request_id` for
/// error-reply correlation). All other fields are skipped lexically.
const EXTRACT_KEYS: [&str; 8] =
    ["type", "request_id", "limit", "points", "name", "since", "chip", "undrain"];

/// Keys that only appear on data-plane verbs: seeing one means this line
/// is not a control request, so bail immediately instead of lexing a
/// multi-kilobyte q/k/v array for nothing.
const DATA_KEYS: [&str; 8] = ["q", "k", "v", "x", "tokens", "kernel", "mode", "session"];

/// Scan one request line. `Some(obj)` holds a minimal object with just
/// the control fields; `None` means "not confidently a control verb —
/// run the full parser".
pub fn scan_control_line(line: &str) -> Option<Json> {
    let mut p = Scan { b: line.as_bytes(), pos: 0 };
    p.ws();
    if p.peek() != Some(b'{') {
        return None;
    }
    p.pos += 1;
    let mut out: BTreeMap<String, Json> = BTreeMap::new();
    p.ws();
    if p.peek() == Some(b'}') {
        return None; // no "type" key: let the full parser shape the error
    }
    loop {
        p.ws();
        let key = p.plain_key()?;
        if DATA_KEYS.contains(&key) {
            return None;
        }
        p.ws();
        if p.peek() != Some(b':') {
            return None;
        }
        p.pos += 1;
        p.ws();
        if EXTRACT_KEYS.contains(&key) {
            let v = p.scalar()?;
            // duplicate keys: last one wins, matching the full parser
            out.insert(key.to_string(), v);
        } else {
            p.skip_value()?;
        }
        p.ws();
        match p.peek() {
            Some(b',') => p.pos += 1,
            Some(b'}') => {
                p.pos += 1;
                break;
            }
            _ => return None,
        }
    }
    p.ws();
    if p.pos != p.b.len() {
        return None; // trailing bytes: the full parser owns that error
    }
    match out.get("type") {
        Some(Json::Str(t)) if CONTROL_VERBS.contains(&t.as_str()) => Some(Json::Obj(out)),
        _ => None,
    }
}

struct Scan<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Scan<'a> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn ws(&mut self) {
        while self.pos < self.b.len() && self.b[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    /// An object key with no escapes — borrowed straight from the line.
    /// Escaped keys (which no client of this protocol emits) bail to the
    /// full parser.
    fn plain_key(&mut self) -> Option<&'a str> {
        if self.peek() != Some(b'"') {
            return None;
        }
        let start = self.pos + 1;
        let mut i = start;
        while i < self.b.len() {
            match self.b[i] {
                b'"' => {
                    self.pos = i + 1;
                    return std::str::from_utf8(&self.b[start..i]).ok();
                }
                b'\\' => return None,
                _ => i += 1,
            }
        }
        None
    }

    /// A scalar JSON value (string/number/bool/null). Arrays and objects
    /// in a needed field return `None` — dispatch would reject them
    /// anyway, and the full parser produces the pinned error text.
    fn scalar(&mut self) -> Option<Json> {
        match self.peek()? {
            b'"' => {
                let s = self.plain_key()?; // same lexing as keys: no escapes
                Some(Json::Str(s.to_string()))
            }
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            c if c == b'-' || c.is_ascii_digit() => {
                let start = self.pos;
                while let Some(c) = self.peek() {
                    if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                        self.pos += 1;
                    } else {
                        break;
                    }
                }
                let text = std::str::from_utf8(&self.b[start..self.pos]).ok()?;
                text.parse::<f64>().ok().map(Json::Num)
            }
            _ => None,
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Option<Json> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Some(v)
        } else {
            None
        }
    }

    /// Skip any JSON value without building it: strings escape-aware,
    /// containers by depth counting, scalars lexically.
    fn skip_value(&mut self) -> Option<()> {
        match self.peek()? {
            b'"' => self.skip_string(),
            b'{' | b'[' => {
                let mut depth = 0usize;
                loop {
                    match self.peek()? {
                        b'{' | b'[' => {
                            depth += 1;
                            self.pos += 1;
                        }
                        b'}' | b']' => {
                            depth -= 1;
                            self.pos += 1;
                            if depth == 0 {
                                return Some(());
                            }
                        }
                        b'"' => self.skip_string()?,
                        _ => self.pos += 1,
                    }
                }
            }
            // skipped scalars are still validated (a bad literal must
            // fall back so the full parser can shape its error);
            // containers are the one place skipping stays purely lexical
            _ => self.scalar().map(|_| ()),
        }
    }

    fn skip_string(&mut self) -> Option<()> {
        debug_assert_eq!(self.peek(), Some(b'"'));
        self.pos += 1;
        while let Some(c) = self.peek() {
            self.pos += 1;
            match c {
                b'"' => return Some(()),
                b'\\' => self.pos += 1, // skip the escaped byte
                _ => {}
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scanned(line: &str) -> Json {
        scan_control_line(line).unwrap_or_else(|| panic!("scanner refused {line:?}"))
    }

    #[test]
    fn control_verbs_scan_to_minimal_objects() {
        let j = scanned(r#"{"type":"ping"}"#);
        assert_eq!(j.get("type").and_then(|t| t.as_str()), Some("ping"));

        let j = scanned(r#"{"type":"trace","limit":32,"request_id":7701}"#);
        assert_eq!(j.get("limit"), Some(&Json::Num(32.0)));
        assert_eq!(j.get("request_id"), Some(&Json::Num(7701.0)));

        let j = scanned(r#"{"type":"series","name":"imka_canary_rel_err{","points":8}"#);
        assert_eq!(j.get("name").and_then(|n| n.as_str()), Some("imka_canary_rel_err{"));
        assert_eq!(j.get("points"), Some(&Json::Num(8.0)));

        let j = scanned(r#"{"type":"drain","chip":0,"undrain":true}"#);
        assert_eq!(j.get("chip"), Some(&Json::Num(0.0)));
        assert_eq!(j.get("undrain"), Some(&Json::Bool(true)));
    }

    /// The scanner must agree with the full parser on every line it
    /// accepts — including bad-typed fields whose errors dispatch shapes.
    #[test]
    fn scanned_fields_match_full_parse() {
        for line in [
            r#"{"type":"trace","limit":0}"#,
            r#"{"type":"trace","limit":2.5}"#,
            r#"{"type":"trace","limit":-3}"#,
            r#"{"type":"trace","limit":"many"}"#,
            r#"{"type":"trace","limit":4294967296}"#,
            r#"{"type":"events","since":-1,"limit":1}"#,
            r#"{"type":"series","points":0}"#,
            r#"{ "type" : "ping" }"#,
            r#"{"type":"stats","extra":{"nested":[1,2,{"d":3}]},"limit":5}"#,
        ] {
            let full = Json::parse(line).unwrap();
            let mini = scanned(line);
            for key in super::EXTRACT_KEYS {
                assert_eq!(mini.get(key), full.get(key), "{line} key {key}");
            }
        }
    }

    #[test]
    fn non_control_lines_fall_back() {
        // data-plane verbs bail early on their payload keys
        assert!(scan_control_line(r#"{"type":"features","kernel":"rbf","x":[1,2]}"#).is_none());
        assert!(scan_control_line(r#"{"q":[1],"k":[1],"v":[1],"type":"attn_append"}"#).is_none());
        assert!(scan_control_line(r#"{"session":3,"type":"attn_close"}"#).is_none());
        // malformed / untyped lines defer to the full parser's errors
        assert!(scan_control_line("this is not json").is_none());
        assert!(scan_control_line("[1, 2, 3]").is_none());
        assert!(scan_control_line("42").is_none());
        assert!(scan_control_line(r#"{"no_type_key": true}"#).is_none());
        assert!(scan_control_line(r#"{"type":17}"#).is_none());
        assert!(scan_control_line(r#"{"type":"frobnicate"}"#).is_none());
        assert!(scan_control_line(r#"{"type":"ping"} trailing"#).is_none());
        assert!(scan_control_line(r#"{"type":"ping","limit":[1]}"#).is_none());
        assert!(scan_control_line(r#"{"type":"ping","#).is_none());
        assert!(scan_control_line("{}").is_none());
    }

    #[test]
    fn duplicate_keys_last_one_wins_like_the_full_parser() {
        let line = r#"{"type":"trace","limit":1,"limit":9}"#;
        assert_eq!(scanned(line).get("limit"), Some(&Json::Num(9.0)));
        assert_eq!(Json::parse(line).unwrap().get("limit"), Some(&Json::Num(9.0)));
    }
}
