//! Native digital execution — the artifact-free twin of the XLA serving
//! artifacts.
//!
//! The offline build's PJRT stub (`xla_stub`) fails every artifact
//! execution, which used to leave `PathLane::Digital` feature requests
//! (and the digital postprocess half of rbf/softmax analog requests)
//! unservable without a real XLA toolchain. This module serves those
//! shapes directly through `linalg::matmul` (cache-blocked, worker-pool
//! threaded) and `features::postprocess`, so the digital substrate is
//! always available — including as the dispatch cost model's fast path
//! for small batches (`fleet::dispatch`). Artifact geometry (d, m,
//! out_dim) still comes from the manifest; only execution is native.
//!
//! Performer classification remains artifact-only: the transformer
//! forward exists as compiled XLA programs, not as native kernels, so
//! `Lane::Performer` requests still require a real PJRT runtime (see
//! docs/dispatch.md).

use crate::features;
use crate::kernels::Kernel;
use crate::linalg::Mat;

/// Full digital feature map z = postprocess(x·Ω): the batch-sized
/// replacement for the `feature_map` XLA artifact. `x` is `n`×`d`,
/// `omega` is `d`×`m`, the result is `n`×`l(kernel)·m` — no padding to
/// an artifact batch size, no `.hlo.txt` on disk.
pub fn feature_forward(kernel: Kernel, x: &Mat, omega: &Mat) -> Mat {
    features::feature_map(kernel, x, omega)
}

/// Digital combine half of the analog path: postprocess the fleet's
/// analog projection `u = x·Ω` (with `x` supplying the row norms the
/// softmax kernel needs). Replaces the per-kernel postprocess artifacts
/// for all three kernels.
pub fn analog_postprocess(kernel: Kernel, u: &Mat, x: &Mat) -> Mat {
    features::postprocess(kernel, u, Some(x))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::{sample_omega, Sampler};
    use crate::kernels::Kernel;
    use crate::util::rng::Rng;

    fn gaussian_mat(rows: usize, cols: usize, seed: u64) -> Mat {
        Mat::randn(rows, cols, &mut Rng::new(seed))
    }

    #[test]
    fn feature_forward_shapes_and_finiteness_all_kernels() {
        let (d, m) = (16, 64);
        let omega = sample_omega(Sampler::Orf, d, m, &mut Rng::new(5));
        // batch sizes an artifact registry would have had to pad or split
        for n in [1, 3, 8, 37] {
            let x = gaussian_mat(n, d, 100 + n as u64);
            for kernel in [Kernel::Rbf, Kernel::ArcCos0, Kernel::Softmax] {
                let z = feature_forward(kernel, &x, &omega);
                assert_eq!((z.rows, z.cols), (n, kernel.l() * m), "{kernel:?} n={n}");
                assert!(z.data.iter().all(|v| v.is_finite()), "{kernel:?} n={n}");
            }
        }
    }

    /// The analog combine must be the exact digital tail of the full
    /// forward: projecting digitally and then postprocessing natively
    /// reproduces `feature_forward` bit-for-bit (maps.rs pins the same
    /// split/full identity; this pins it through the runtime entry
    /// points the engine actually calls).
    #[test]
    fn analog_postprocess_is_the_tail_of_feature_forward() {
        let (n, d, m) = (9, 16, 32);
        let omega = sample_omega(Sampler::Orf, d, m, &mut Rng::new(9));
        let x = gaussian_mat(n, d, 42);
        let u = crate::linalg::matmul(&x, &omega);
        for kernel in [Kernel::Rbf, Kernel::ArcCos0, Kernel::Softmax] {
            let full = feature_forward(kernel, &x, &omega);
            let split = analog_postprocess(kernel, &u, &x);
            assert_eq!(full.data, split.data, "{kernel:?}");
        }
    }
}
