//! Thin typed wrapper over the `xla` crate's PJRT CPU client.
//!
//! Interchange is HLO *text* (see /opt/xla-example/README.md): jax >= 0.5
//! emits HloModuleProto with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids and round-trips cleanly.

use std::path::Path;
use std::sync::Mutex;

// Offline PJRT stand-in; swap back to the real `xla` crate by deleting
// this alias when the build environment provides it.
use super::xla_stub as xla;
use crate::error::{Error, Result};
use crate::linalg::Mat;

/// Typed inputs for an executable.
#[derive(Clone, Debug)]
pub enum Input {
    /// f32 tensor with shape
    F32(Vec<f32>, Vec<usize>),
    /// i32 tensor with shape
    I32(Vec<i32>, Vec<usize>),
    /// i32 scalar
    ScalarI32(i32),
}

impl Input {
    pub fn from_mat(m: &Mat) -> Input {
        Input::F32(m.data.clone(), vec![m.rows, m.cols])
    }

    pub fn vec_f32(v: Vec<f32>) -> Input {
        let n = v.len();
        Input::F32(v, vec![n])
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            Input::F32(data, shape) => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
            Input::I32(data, shape) => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
            Input::ScalarI32(v) => xla::Literal::from(*v),
        })
    }
}

/// All PJRT objects share non-atomically-refcounted internals (`Rc`), so
/// every PJRT call in the process is serialized through this one lock.
/// XLA's CPU backend parallelizes *inside* an execution with its own
/// thread pool, so the coordinator still gets intra-op parallelism.
static PJRT_LOCK: Mutex<()> = Mutex::new(());

/// A compiled artifact ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

// SAFETY: all PJRT access (compile + execute) is serialized through
// PJRT_LOCK, so the non-Send internals are never touched concurrently.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Run with typed inputs; returns the single (tuple-unwrapped) f32
    /// output as a flat vector plus its element count.
    pub fn run_f32(&self, inputs: &[Input]) -> Result<Vec<f32>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|i| i.to_literal())
            .collect::<Result<_>>()?;
        // poison-tolerant: a panic in another thread (e.g. a failing test)
        // must not wedge every subsequent PJRT call in the process
        let guard = PJRT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        drop(guard);
        // aot.py lowers with return_tuple=True -> 1-tuple
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Run and reshape the output into a matrix of the given shape.
    pub fn run_mat(&self, inputs: &[Input], rows: usize, cols: usize) -> Result<Mat> {
        let v = self.run_f32(inputs)?;
        if v.len() != rows * cols {
            return Err(Error::Shape(format!(
                "{}: output has {} elems, expected {rows}x{cols}",
                self.name,
                v.len()
            )));
        }
        Ok(Mat::from_vec(rows, cols, v))
    }
}

/// The PJRT CPU runtime: client + compiler.
pub struct XlaRuntime {
    client: xla::PjRtClient,
}

// SAFETY: all use of the client goes through PJRT_LOCK (see compile_file).
unsafe impl Send for XlaRuntime {}
unsafe impl Sync for XlaRuntime {}

impl XlaRuntime {
    pub fn cpu() -> Result<XlaRuntime> {
        Ok(XlaRuntime { client: xla::PjRtClient::cpu()? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact.
    pub fn compile_file(&self, path: &Path, name: &str) -> Result<Executable> {
        if !path.exists() {
            return Err(Error::Artifact(format!(
                "artifact file missing: {} (run `make artifacts`)",
                path.display()
            )));
        }
        let guard = PJRT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        drop(guard);
        Ok(Executable { exe, name: name.to_string() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn compile_and_run_feature_artifact() {
        let dir = artifacts_dir();
        let path = dir.join("feature_rbf_b8_d16_m256.hlo.txt");
        if !path.exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = XlaRuntime::cpu().unwrap();
        let exe = rt.compile_file(&path, "feature_rbf").unwrap();
        let mut rng = crate::util::Rng::new(0);
        let x = Mat::randn(8, 16, &mut rng);
        let omega = Mat::randn(16, 256, &mut rng);
        let z = exe
            .run_mat(&[Input::from_mat(&x), Input::from_mat(&omega)], 8, 512)
            .unwrap();
        // must match the rust-native RBF feature map
        let want = crate::features::feature_map(crate::kernels::Kernel::Rbf, &x, &omega);
        let rel = crate::util::stats::rel_fro_error(&z.data, &want.data);
        assert!(rel < 1e-4, "xla vs native rel err {rel}");
    }

    #[test]
    fn missing_artifact_is_clean_error() {
        let rt = XlaRuntime::cpu().unwrap();
        let err = match rt.compile_file(Path::new("/nonexistent/x.hlo.txt"), "x") {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        assert!(err.to_string().contains("artifact file missing"));
    }
}
