//! Trained-model bundle: weights npz + test set npz + the input-marshalling
//! logic that feeds Performer artifacts (tokens, params in sorted name
//! order, Ω, seed — the exact flattening order `aot.py` lowered with).

use std::collections::BTreeMap;
use std::path::Path;

use super::artifact::ArtifactSpec;
use super::client::Input;
use crate::error::{Error, Result};
use crate::linalg::Mat;
use crate::npy::{read_npz, NpyArray};

/// Loaded model weights + held-out evaluation data.
pub struct ModelBundle {
    /// parameter name -> array
    pub params: BTreeMap<String, NpyArray>,
    /// FAVOR+ mapping matrix exported at training time (d_head x m)
    pub omega: Mat,
    /// held-out tokens (n x seq_len)
    pub test_tokens: Vec<i32>,
    pub test_labels: Vec<usize>,
    pub n_test: usize,
    pub seq_len: usize,
}

impl ModelBundle {
    /// Load `weights_<task>.npz` + `testset_<task>.npz` from `dir`.
    pub fn load(dir: &Path, weights_file: &str, testset_file: &str) -> Result<ModelBundle> {
        let mut params = read_npz(&dir.join(weights_file))?;
        let omega_arr = params
            .remove("__omega__")
            .ok_or_else(|| Error::Artifact("weights npz missing __omega__".into()))?;
        let omega = to_mat(&omega_arr)?;

        let test = read_npz(&dir.join(testset_file))?;
        let tokens_arr = test
            .get("tokens")
            .ok_or_else(|| Error::Artifact("testset npz missing tokens".into()))?;
        let labels_arr = test
            .get("labels")
            .ok_or_else(|| Error::Artifact("testset npz missing labels".into()))?;
        let (n_test, seq_len) = match tokens_arr.shape.as_slice() {
            [n, l] => (*n, *l),
            s => return Err(Error::Shape(format!("tokens shape {s:?}"))),
        };
        let test_tokens: Vec<i32> = tokens_arr
            .as_i64_vec()?
            .into_iter()
            .map(|v| v as i32)
            .collect();
        let test_labels: Vec<usize> = labels_arr
            .as_i64_vec()?
            .into_iter()
            .map(|v| v as usize)
            .collect();
        Ok(ModelBundle { params, omega, test_tokens, test_labels, n_test, seq_len })
    }

    /// Rows [i0, i1) of the test set as a token batch.
    pub fn token_batch(&self, i0: usize, i1: usize) -> Vec<i32> {
        self.test_tokens[i0 * self.seq_len..i1 * self.seq_len].to_vec()
    }

    /// Marshal inputs for a performer artifact: (tokens, params sorted by
    /// name, omega, seed). `omega_override` substitutes a (possibly
    /// chip-programmed noisy) mapping matrix; `param_override` substitutes
    /// individual parameter tensors (full on-chip deployment).
    pub fn performer_inputs(
        &self,
        spec: &ArtifactSpec,
        tokens: &[i32],
        seed: i32,
        omega_override: Option<&Mat>,
        param_override: Option<&BTreeMap<String, Mat>>,
    ) -> Result<Vec<Input>> {
        let batch = spec.batch();
        let expected = batch * self.seq_len;
        if tokens.len() != expected {
            return Err(Error::Shape(format!(
                "{}: got {} tokens, expected {batch}x{}",
                spec.name,
                tokens.len(),
                self.seq_len
            )));
        }
        let names: Vec<String> = spec
            .meta
            .req("param_names")?
            .as_arr()
            .ok_or_else(|| Error::Parse("param_names not an array".into()))?
            .iter()
            .filter_map(|v| v.as_str().map(|s| s.to_string()))
            .collect();

        let mut inputs = Vec::with_capacity(names.len() + 3);
        inputs.push(Input::I32(tokens.to_vec(), vec![batch, self.seq_len]));
        for name in &names {
            if let Some(over) = param_override.and_then(|m| m.get(name)) {
                let arr = self.params.get(name).ok_or_else(|| {
                    Error::Artifact(format!("weights npz missing param '{name}'"))
                })?;
                inputs.push(Input::F32(over.data.clone(), arr.shape.clone()));
            } else {
                let arr = self.params.get(name).ok_or_else(|| {
                    Error::Artifact(format!("weights npz missing param '{name}'"))
                })?;
                inputs.push(Input::F32(arr.as_f32()?.to_vec(), arr.shape.clone()));
            }
        }
        let om = omega_override.unwrap_or(&self.omega);
        inputs.push(Input::F32(om.data.clone(), vec![om.rows, om.cols]));
        inputs.push(Input::ScalarI32(seed));
        Ok(inputs)
    }

    /// Parameter tensor as a 2-D matrix (errors on other ranks).
    pub fn param_mat(&self, name: &str) -> Result<Mat> {
        let arr = self
            .params
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("missing param '{name}'")))?;
        to_mat(arr)
    }

    /// Names of all 2-D parameters (the MVM weights that go on-chip in
    /// the full-deployment mode).
    pub fn matrix_param_names(&self) -> Vec<String> {
        self.params
            .iter()
            .filter(|(_, a)| a.shape.len() == 2)
            .map(|(n, _)| n.clone())
            .collect()
    }
}

fn to_mat(arr: &NpyArray) -> Result<Mat> {
    match arr.shape.as_slice() {
        [r, c] => Ok(Mat::from_vec(*r, *c, arr.as_f32()?.to_vec())),
        s => Err(Error::Shape(format!("expected 2-d array, got {s:?}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn load_real_bundle() {
        let dir = artifacts_dir();
        if !dir.join("weights_pattern.npz").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let b = ModelBundle::load(&dir, "weights_pattern.npz", "testset_pattern.npz").unwrap();
        assert!(b.params.len() > 20);
        assert!(b.params.contains_key("embed.tok"));
        assert_eq!(b.omega.rows, 32); // d_head
        assert_eq!(b.test_tokens.len(), b.n_test * b.seq_len);
        assert!(b.test_labels.iter().all(|&l| l < 2));
        assert!(!b.matrix_param_names().is_empty());
    }
}
