//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them once on the CPU PJRT client, and
//! executes them from the request path. Python is never invoked here.

pub mod artifact;
pub mod client;
pub mod weights;
pub mod xla_stub;

pub use artifact::{ArtifactSpec, Registry};
pub use client::{Executable, Input, XlaRuntime};
pub use weights::ModelBundle;
