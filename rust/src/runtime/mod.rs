//! Runtime layer: XLA artifacts plus the native digital fallback.
//!
//! - [`artifact`] / [`client`] / [`weights`] — loads the HLO-text
//!   artifacts produced by `python/compile/aot.py`, compiles them once on
//!   the CPU PJRT client, and executes them from the request path.
//!   Python is never invoked here.
//! - [`native`] — artifact-free digital execution of the feature-map
//!   shapes through `linalg::matmul`, so the digital substrate serves
//!   even where no PJRT runtime exists (see [`xla_stub`]).

pub mod artifact;
pub mod client;
pub mod native;
pub mod weights;
pub mod xla_stub;

pub use artifact::{ArtifactSpec, Registry};
pub use client::{Executable, Input, XlaRuntime};
pub use weights::ModelBundle;
