//! Artifact registry: parses `artifacts/manifest.json`, compiles HLO-text
//! artifacts lazily, and caches executables by name.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use super::client::{Executable, XlaRuntime};
use crate::config::Json;
use crate::error::{Error, Result};

/// One manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub kind: String,
    /// full manifest entry (kind-specific fields: batch, d, m, mode, ...)
    pub meta: Json,
    /// flattened input (shape, dtype) list in parameter order
    pub input_shapes: Vec<Vec<usize>>,
}

impl ArtifactSpec {
    pub fn batch(&self) -> usize {
        self.meta.get("batch").and_then(|v| v.as_usize()).unwrap_or(1)
    }

    pub fn out_dim(&self) -> Option<usize> {
        self.meta.get("out_dim").and_then(|v| v.as_usize())
    }
}

/// Registry over one artifacts directory.
pub struct Registry {
    pub dir: PathBuf,
    pub specs: BTreeMap<String, ArtifactSpec>,
    pub manifest: Json,
    runtime: Arc<XlaRuntime>,
    cache: Mutex<BTreeMap<String, Arc<Executable>>>,
}

impl Registry {
    /// Open `dir/manifest.json` and index its artifacts.
    pub fn open(dir: &Path) -> Result<Registry> {
        let manifest_path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} ({e}); run `make artifacts` first",
                manifest_path.display()
            ))
        })?;
        let manifest = Json::parse(&src)?;
        let mut specs = BTreeMap::new();
        for a in manifest
            .req("artifacts")?
            .as_arr()
            .ok_or_else(|| Error::Parse("manifest.artifacts not an array".into()))?
        {
            let name = a.req_str("name")?.to_string();
            let input_shapes = a
                .req("inputs")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|i| {
                    i.get("shape").and_then(|s| s.as_arr()).map(|dims| {
                        dims.iter().filter_map(|d| d.as_usize()).collect::<Vec<_>>()
                    })
                })
                .collect();
            specs.insert(
                name.clone(),
                ArtifactSpec {
                    name,
                    file: a.req_str("file")?.to_string(),
                    kind: a.req_str("kind")?.to_string(),
                    meta: a.clone(),
                    input_shapes,
                },
            );
        }
        Ok(Registry {
            dir: dir.to_path_buf(),
            specs,
            manifest,
            runtime: Arc::new(XlaRuntime::cpu()?),
            cache: Mutex::new(BTreeMap::new()),
        })
    }

    pub fn names(&self) -> Vec<&str> {
        self.specs.keys().map(|s| s.as_str()).collect()
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.specs
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("unknown artifact '{name}'")))
    }

    /// Compile (or fetch the cached) executable for `name`.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.spec(name)?;
        let exe = Arc::new(
            self.runtime
                .compile_file(&self.dir.join(&spec.file), name)?,
        );
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Artifacts of a kind, e.g. all `performer` variants.
    pub fn of_kind(&self, kind: &str) -> Vec<&ArtifactSpec> {
        self.specs.values().filter(|s| s.kind == kind).collect()
    }

    /// Find the smallest-batch variant of a (kind, filter) that fits `n`
    /// rows; falls back to the largest if n exceeds every batch size.
    pub fn best_batch<'a>(
        &'a self,
        kind: &str,
        n: usize,
        pred: impl Fn(&ArtifactSpec) -> bool,
    ) -> Option<&'a ArtifactSpec> {
        let mut candidates: Vec<&ArtifactSpec> =
            self.of_kind(kind).into_iter().filter(|s| pred(s)).collect();
        candidates.sort_by_key(|s| s.batch());
        candidates
            .iter()
            .find(|s| s.batch() >= n)
            .copied()
            .or_else(|| candidates.last().copied())
    }

    pub fn model_config(&self) -> Option<&Json> {
        self.manifest.get("model_config")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn open_real_manifest() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let reg = Registry::open(&dir).unwrap();
        assert!(reg.specs.len() >= 10);
        assert!(!reg.of_kind("feature_map").is_empty());
        assert!(!reg.of_kind("performer").is_empty());
        // every referenced file exists
        for spec in reg.specs.values() {
            assert!(dir.join(&spec.file).exists(), "{} missing", spec.file);
        }
    }

    #[test]
    fn best_batch_picks_smallest_fit() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let reg = Registry::open(&dir).unwrap();
        let pick = |n: usize| {
            reg.best_batch("feature_map", n, |s| {
                s.meta.get("kernel").and_then(|k| k.as_str()) == Some("rbf")
            })
            .map(|s| s.batch())
        };
        assert_eq!(pick(1), Some(1));
        assert_eq!(pick(2), Some(8));
        assert_eq!(pick(8), Some(8));
        assert_eq!(pick(9), Some(64));
        assert_eq!(pick(1000), Some(64)); // falls back to largest
    }

    #[test]
    fn missing_dir_is_clean_error() {
        let err = match Registry::open(Path::new("/nonexistent")) {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        assert!(err.to_string().contains("make artifacts"));
    }
}
