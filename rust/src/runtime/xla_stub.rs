//! Offline stand-in for the `xla` (xla-rs / PJRT) crate.
//!
//! The build environment resolves no external crates (DESIGN.md
//! §Toolchain substitutions), so this module mirrors the small slice of
//! the xla-rs API surface that [`super::client`] consumes. Construction of
//! clients and literals succeeds so the registry can open and index
//! manifests; anything that would actually need the PJRT runtime
//! (compiling HLO, executing) returns [`Error`] with an actionable
//! message. `super::client` aliases this module as `xla`, so swapping the
//! real crate back in is a one-line change there.
//!
//! What still works under the stub: the simulated chip (all analog MVMs),
//! the native feature maps, and — since the engine's feature path runs
//! through [`super::native`] — every feature lane on both substrates:
//! digital requests execute `linalg::matmul` + native postprocess, and
//! analog requests postprocess natively for all three kernels. The only
//! thing that still needs a real PJRT runtime is the performer
//! (transformer classification) lane, whose forward exists solely as
//! compiled XLA programs. Performer tests skip when artifacts are
//! absent; in an environment with artifacts and the real xla crate,
//! restore the alias in `super::client` to re-enable that lane — and to
//! give `fleet::dispatch` a second, XLA-backed digital substrate to
//! score (tracked in ROADMAP "Real PJRT backend").

use std::path::Path;

/// Mirror of `xla::Error` (message-only).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

impl From<Error> for crate::error::Error {
    fn from(e: Error) -> Self {
        crate::error::Error::Xla(e.0)
    }
}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT runtime not available in this offline build — \
         XLA artifacts cannot compile or execute (chip-simulator MVMs and \
         native feature maps still work); swap the real `xla` crate back \
         in via the alias in runtime/client.rs"
    ))
}

/// Host literal (opaque: the stub never materializes device data).
#[derive(Debug, Clone, Default)]
pub struct Literal;

impl Literal {
    /// Accepts any backing buffer; the stub discards it.
    pub fn vec1<T>(_data: T) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(Literal)
    }

    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        Err(unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("Literal::to_literal_sync"))
    }
}

impl From<i32> for Literal {
    fn from(_v: i32) -> Self {
        Literal
    }
}

/// Mirror of `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &Path) -> Result<HloModuleProto, Error> {
        Err(unavailable(&format!(
            "compile of HLO artifact {}",
            path.display()
        )))
    }
}

/// Mirror of `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Mirror of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<Literal>>, Error> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Mirror of `xla::PjRtClient` (CPU).
pub struct PjRtClient;

impl PjRtClient {
    /// Succeeds so `Registry::open` works offline; failures surface at
    /// compile/execute time instead.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_opens_but_compile_fails_cleanly() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "cpu-stub");
        let err = HloModuleProto::from_text_file(Path::new("a.hlo.txt")).unwrap_err();
        assert!(err.to_string().contains("PJRT runtime not available"));
        let e: crate::error::Error = err.into();
        assert!(matches!(e, crate::error::Error::Xla(_)));
    }

    #[test]
    fn literal_construction_is_infallible() {
        let l = Literal::vec1(&vec![1.0f32, 2.0]).reshape(&[1, 2]).unwrap();
        assert!(l.to_vec::<f32>().is_err());
    }
}
