//! Crate-wide error type.

/// Result alias used across the crate.
pub type Result<T> = std::result::Result<T, Error>;

/// Unified error for the library layers.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("shape error: {0}")]
    Shape(String),

    #[error("numerical error: {0}")]
    Numerical(String),

    #[error("config error: {0}")]
    Config(String),

    #[error("parse error: {0}")]
    Parse(String),

    #[error("artifact error: {0}")]
    Artifact(String),

    #[error("chip error: {0}")]
    Chip(String),

    #[error("coordinator error: {0}")]
    Coordinator(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("xla error: {0}")]
    Xla(String),

    #[error("{0}")]
    Msg(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl Error {
    pub fn msg<S: Into<String>>(s: S) -> Self {
        Error::Msg(s.into())
    }
}
