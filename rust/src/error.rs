//! Crate-wide error type (hand-rolled: the offline build resolves no
//! `thiserror`, see DESIGN.md §Toolchain substitutions).

/// Result alias used across the crate.
pub type Result<T> = std::result::Result<T, Error>;

/// Unified error for the library layers.
#[derive(Debug)]
pub enum Error {
    Shape(String),
    Numerical(String),
    Config(String),
    Parse(String),
    Artifact(String),
    Chip(String),
    Coordinator(String),
    Io(std::io::Error),
    Xla(String),
    Msg(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Shape(s) => write!(f, "shape error: {s}"),
            Error::Numerical(s) => write!(f, "numerical error: {s}"),
            Error::Config(s) => write!(f, "config error: {s}"),
            Error::Parse(s) => write!(f, "parse error: {s}"),
            Error::Artifact(s) => write!(f, "artifact error: {s}"),
            Error::Chip(s) => write!(f, "chip error: {s}"),
            Error::Coordinator(s) => write!(f, "coordinator error: {s}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(s) => write!(f, "xla error: {s}"),
            Error::Msg(s) => write!(f, "{s}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    pub fn msg<S: Into<String>>(s: S) -> Self {
        Error::Msg(s.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_keeps_layer_prefixes() {
        assert_eq!(Error::Chip("boom".into()).to_string(), "chip error: boom");
        assert_eq!(
            Error::Coordinator("x".into()).to_string(),
            "coordinator error: x"
        );
        assert_eq!(Error::msg("plain").to_string(), "plain");
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(e.to_string().contains("gone"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
