//! UCI-shaped synthetic benchmarks (Fig. 2 substitutes).
//!
//! Each generator matches the published statistics of its namesake
//! (dimension d, class count, train/test sizes from Supp. Table III —
//! scaled down by `scale` to keep sweeps tractable) and picks a nonlinear
//! structure qualitatively matched to the original domain. The Fig. 2
//! experiments measure the FP32-vs-AIMC *delta* of kernel approximation,
//! which depends on the (d, N, nonlinearity) regime, not on the actual UCI
//! bits (DESIGN.md §Substitutions).

use super::synth::{gaussian_mixture, ring, split_dataset, xor, Dataset};
use crate::util::Rng;

/// The six benchmarks of the paper's Fig. 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum UciName {
    Ijcnn,
    Eeg,
    CodRna,
    Magic04,
    Letter,
    Skin,
}

pub const ALL_UCI: [UciName; 6] = [
    UciName::Ijcnn,
    UciName::Eeg,
    UciName::CodRna,
    UciName::Magic04,
    UciName::Letter,
    UciName::Skin,
];

impl UciName {
    pub fn as_str(&self) -> &'static str {
        match self {
            UciName::Ijcnn => "ijcnn01",
            UciName::Eeg => "eeg",
            UciName::CodRna => "cod-rna",
            UciName::Magic04 => "magic04",
            UciName::Letter => "letter",
            UciName::Skin => "skin",
        }
    }

    pub fn parse(s: &str) -> Option<UciName> {
        ALL_UCI.iter().copied().find(|n| n.as_str() == s)
    }

    /// (d, classes) from Supp. Table III.
    pub fn dims(&self) -> (usize, usize) {
        match self {
            UciName::Ijcnn => (22, 2),
            UciName::Eeg => (14, 2),
            UciName::CodRna => (8, 2),
            UciName::Magic04 => (10, 2),
            UciName::Letter => (16, 26),
            UciName::Skin => (3, 2),
        }
    }

    /// Reference (train, test) sizes from Supp. Table III.
    pub fn full_sizes(&self) -> (usize, usize) {
        match self {
            UciName::Ijcnn => (49_990, 91_701),
            UciName::Eeg => (7_490, 7_490),
            UciName::CodRna => (59_535, 157_413),
            UciName::Magic04 => (9_510, 9_510),
            UciName::Letter => (12_000, 6_000),
            UciName::Skin => (122_529, 122_529),
        }
    }
}

/// Generate a benchmark at `scale` (1.0 = paper-size; experiments default
/// to ~0.05 so the full Fig. 2 grid stays tractable on one machine).
pub fn load_uci(name: UciName, seed: u64, scale: f64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0xD1CE_0000 ^ name.as_str().len() as u64);
    let (d, classes) = name.dims();
    let (ftr, fte) = name.full_sizes();
    let n_train = ((ftr as f64 * scale) as usize).clamp(200, 20_000);
    let n_test = ((fte as f64 * scale) as usize).clamp(200, 20_000);
    let n = n_train + n_test;
    let (x, y) = match name {
        // continuous EEG traces: multimodal mixtures
        UciName::Eeg => gaussian_mixture(&mut rng, d, classes, n, 4, 0.8),
        // particle shower shapes: shell structure (signal/background energy)
        UciName::Magic04 => ring(&mut rng, d, n, 0.25),
        // RNA secondary structure: XOR-like interaction of few features
        UciName::CodRna => xor(&mut rng, d, n, 3, 0.15),
        // skin RGB: low-d, two warped blobs
        UciName::Skin => gaussian_mixture(&mut rng, d, classes, n, 2, 0.45),
        // letter: 26-class mixture
        UciName::Letter => gaussian_mixture(&mut rng, d, classes, n, 2, 0.55),
        // ijcnn: engine misfire windows — mixture + shell composite
        UciName::Ijcnn => {
            let (mut xa, mut ya) = gaussian_mixture(&mut rng, d, 2, n / 2, 3, 0.6);
            let (xb, yb) = ring(&mut rng, d, n - n / 2, 0.3);
            xa = crate::linalg::Mat::vstack(&[&xa, &xb]);
            ya.extend(yb);
            (xa, ya)
        }
    };
    split_dataset(name.as_str(), x, y, classes, n_train, &mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_benchmarks_generate() {
        for name in ALL_UCI {
            let ds = load_uci(name, 0, 0.02);
            let (d, classes) = name.dims();
            assert_eq!(ds.d(), d, "{name:?}");
            assert_eq!(ds.classes, classes);
            assert!(ds.train_x.rows >= 200);
            assert!(ds.test_x.rows >= 200);
            assert!(ds.train_y.iter().all(|&c| c < classes));
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = load_uci(UciName::Eeg, 7, 0.02);
        let b = load_uci(UciName::Eeg, 7, 0.02);
        assert_eq!(a.train_x.data, b.train_x.data);
        assert_eq!(a.train_y, b.train_y);
        let c = load_uci(UciName::Eeg, 8, 0.02);
        assert_ne!(a.train_x.data, c.train_x.data);
    }

    #[test]
    fn name_parse_roundtrip() {
        for n in ALL_UCI {
            assert_eq!(UciName::parse(n.as_str()), Some(n));
        }
        assert_eq!(UciName::parse("nope"), None);
    }

    #[test]
    fn letter_is_multiclass() {
        let ds = load_uci(UciName::Letter, 1, 0.02);
        let mut seen = vec![false; 26];
        for &c in &ds.train_y {
            seen[c] = true;
        }
        assert!(seen.iter().filter(|&&s| s).count() >= 20);
    }
}
