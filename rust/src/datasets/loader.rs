//! Optional CSV loader: if real benchmark data is placed under `data/`
//! (e.g. `data/eeg.csv` with the label in the last column), it is used in
//! place of the synthetic generator.

use std::path::Path;

use super::synth::Dataset;
use crate::error::{Error, Result};
use crate::linalg::Mat;
use crate::util::Rng;

/// Parse a headerless CSV of floats, label (integer) in the last column.
pub fn parse_csv(src: &str) -> Result<(Mat, Vec<usize>)> {
    let mut rows: Vec<Vec<f32>> = Vec::new();
    let mut labels = Vec::new();
    let mut width = None;
    for (lineno, line) in src.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').collect();
        if fields.len() < 2 {
            return Err(Error::Parse(format!("csv line {}: too few fields", lineno + 1)));
        }
        match width {
            None => width = Some(fields.len()),
            Some(w) if w != fields.len() => {
                return Err(Error::Parse(format!(
                    "csv line {}: ragged row ({} vs {})",
                    lineno + 1,
                    fields.len(),
                    w
                )))
            }
            _ => {}
        }
        let mut row = Vec::with_capacity(fields.len() - 1);
        for f in &fields[..fields.len() - 1] {
            row.push(
                f.trim()
                    .parse::<f32>()
                    .map_err(|_| Error::Parse(format!("csv line {}: bad float '{f}'", lineno + 1)))?,
            );
        }
        let label: f64 = fields[fields.len() - 1]
            .trim()
            .parse()
            .map_err(|_| Error::Parse(format!("csv line {}: bad label", lineno + 1)))?;
        labels.push(label as i64);
        rows.push(row);
    }
    if rows.is_empty() {
        return Err(Error::Parse("csv: empty".into()));
    }
    // map labels to 0..k (handles -1/+1 and 1..k conventions)
    let mut uniq: Vec<i64> = labels.clone();
    uniq.sort_unstable();
    uniq.dedup();
    let y: Vec<usize> = labels
        .iter()
        .map(|l| uniq.binary_search(l).unwrap())
        .collect();
    let d = rows[0].len();
    let mut x = Mat::zeros(rows.len(), d);
    for (i, row) in rows.iter().enumerate() {
        x.row_mut(i).copy_from_slice(row);
    }
    Ok((x, y))
}

/// Load `data/<name>.csv` if present, split 50/50, normalize.
pub fn try_load_csv(name: &str, data_dir: &Path, seed: u64) -> Result<Option<Dataset>> {
    let path = data_dir.join(format!("{name}.csv"));
    if !path.exists() {
        return Ok(None);
    }
    let src = std::fs::read_to_string(&path)?;
    let (x, y) = parse_csv(&src)?;
    let classes = y.iter().max().map(|m| m + 1).unwrap_or(2);
    let n_train = x.rows / 2;
    let mut rng = Rng::new(seed);
    Ok(Some(super::synth::split_dataset(name, x, y, classes, n_train, &mut rng)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_csv() {
        let (x, y) = parse_csv("1.0,2.0,0\n3.5,-1.0,1\n0.0,0.0,0\n1,1,1\n").unwrap();
        assert_eq!((x.rows, x.cols), (4, 2));
        assert_eq!(y, vec![0, 1, 0, 1]);
    }

    #[test]
    fn maps_pm1_labels() {
        let (_, y) = parse_csv("0,-1\n0,1\n0,-1\n").unwrap();
        assert_eq!(y, vec![0, 1, 0]);
    }

    #[test]
    fn rejects_ragged() {
        assert!(parse_csv("1,2,0\n1,0\n").is_err());
        assert!(parse_csv("").is_err());
        assert!(parse_csv("a,b,0\n").is_err());
    }

    #[test]
    fn missing_file_is_none() {
        let r = try_load_csv("definitely-missing", Path::new("/nonexistent"), 0).unwrap();
        assert!(r.is_none());
    }
}
