//! Synthetic classification generators with controllable nonlinearity.
//!
//! Every generator produces class structure that a *linear* classifier
//! cannot separate but a kernelized one can — the regime in which the
//! paper's Fig. 2 experiments live.

use crate::linalg::Mat;
use crate::util::Rng;

/// A labelled dataset split into train/test, normalized feature-wise.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub train_x: Mat,
    pub train_y: Vec<usize>,
    pub test_x: Mat,
    pub test_y: Vec<usize>,
    pub classes: usize,
}

impl Dataset {
    pub fn d(&self) -> usize {
        self.train_x.cols
    }

    /// Normalize columns to zero mean / unit variance using train stats
    /// (the paper's preprocessing — reduces INT8 quantization error).
    pub fn normalize(&mut self) {
        let (mu, sd) = self.train_x.normalize_columns();
        self.test_x.apply_normalization(&mu, &sd);
    }
}

/// Anisotropic Gaussian-mixture classes on nonlinearly warped manifolds.
///
/// Per class we sample `modes_per_class` mixture centers; points are drawn
/// around a center, rotated, and pushed through a mild nonlinearity
/// (coordinate-coupled sin warp) so the Bayes boundary is curved.
pub fn gaussian_mixture(
    rng: &mut Rng,
    d: usize,
    classes: usize,
    n: usize,
    modes_per_class: usize,
    spread: f32,
) -> (Mat, Vec<usize>) {
    // centers: classes x modes x d
    let mut centers = Vec::with_capacity(classes * modes_per_class);
    for _ in 0..classes * modes_per_class {
        let mut c = vec![0.0f32; d];
        for v in &mut c {
            *v = 2.0 * rng.gaussian_f32();
        }
        centers.push(c);
    }
    let mut x = Mat::zeros(n, d);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let cls = rng.below(classes);
        let mode = rng.below(modes_per_class);
        let center = &centers[cls * modes_per_class + mode];
        let row = x.row_mut(i);
        for (j, v) in row.iter_mut().enumerate() {
            *v = center[j] + spread * rng.gaussian_f32();
        }
        // nonlinear warp coupling coordinates (keeps classes separable by
        // RBF-like kernels, not by hyperplanes)
        for j in 0..d {
            let k = (j + 1) % d;
            row[j] += 0.5 * (row[k] * 1.3).sin();
        }
        y.push(cls);
    }
    (x, y)
}

/// Concentric hyperspherical shells (binary): radius decides the class.
/// Classic kernel-separable / linearly-inseparable structure.
pub fn ring(rng: &mut Rng, d: usize, n: usize, noise: f32) -> (Mat, Vec<usize>) {
    let mut x = Mat::zeros(n, d);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let cls = rng.below(2);
        let target_r = if cls == 0 { 1.0f32 } else { 2.0f32 };
        let row = x.row_mut(i);
        let mut norm2 = 0.0f32;
        for v in row.iter_mut() {
            *v = rng.gaussian_f32();
            norm2 += *v * *v;
        }
        let scale = target_r / norm2.sqrt().max(1e-6);
        for v in row.iter_mut() {
            *v = *v * scale + noise * rng.gaussian_f32();
        }
        y.push(cls);
    }
    (x, y)
}

/// XOR-of-quadrants in the first `k` dims (binary), rest is noise.
pub fn xor(rng: &mut Rng, d: usize, n: usize, k: usize, noise: f32) -> (Mat, Vec<usize>) {
    assert!(k >= 2 && k <= d);
    let mut x = Mat::zeros(n, d);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let row = x.row_mut(i);
        for v in row.iter_mut() {
            *v = rng.gaussian_f32();
        }
        let mut parity = 0usize;
        for v in row.iter().take(k) {
            if *v > 0.0 {
                parity ^= 1;
            }
        }
        for v in row.iter_mut() {
            *v += noise * rng.gaussian_f32();
        }
        y.push(parity);
    }
    (x, y)
}

/// Assemble a Dataset from a generator output with a random split.
pub fn split_dataset(
    name: &str,
    x: Mat,
    y: Vec<usize>,
    classes: usize,
    n_train: usize,
    rng: &mut Rng,
) -> Dataset {
    let n = x.rows;
    assert!(n_train < n);
    let idx = rng.sample_indices(n, n);
    let train_idx = &idx[..n_train];
    let test_idx = &idx[n_train..];
    let mut ds = Dataset {
        name: name.to_string(),
        train_x: x.select_rows(train_idx),
        train_y: train_idx.iter().map(|&i| y[i]).collect(),
        test_x: x.select_rows(test_idx),
        test_y: test_idx.iter().map(|&i| y[i]).collect(),
        classes,
    };
    ds.normalize();
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixture_shapes_and_labels() {
        let mut rng = Rng::new(0);
        let (x, y) = gaussian_mixture(&mut rng, 8, 3, 500, 2, 0.5);
        assert_eq!(x.rows, 500);
        assert_eq!(y.len(), 500);
        assert!(y.iter().all(|&c| c < 3));
        // all classes present
        for c in 0..3 {
            assert!(y.iter().any(|&v| v == c));
        }
    }

    #[test]
    fn ring_radii_separate() {
        let mut rng = Rng::new(1);
        let (x, y) = ring(&mut rng, 6, 400, 0.05);
        let mut r0 = 0.0;
        let mut n0 = 0;
        let mut r1 = 0.0;
        let mut n1 = 0;
        for i in 0..400 {
            let r: f32 = x.row(i).iter().map(|v| v * v).sum::<f32>().sqrt();
            if y[i] == 0 {
                r0 += r as f64;
                n0 += 1;
            } else {
                r1 += r as f64;
                n1 += 1;
            }
        }
        assert!(r1 / n1 as f64 > 1.5 * (r0 / n0 as f64));
    }

    #[test]
    fn xor_not_linearly_biased() {
        let mut rng = Rng::new(2);
        let (x, y) = xor(&mut rng, 5, 2000, 2, 0.05);
        // mean of each feature conditioned on the class should be ~0
        for j in 0..2 {
            let mut m0 = 0.0;
            let mut m1 = 0.0;
            let (mut c0, mut c1) = (0, 0);
            for i in 0..2000 {
                if y[i] == 0 {
                    m0 += x.at(i, j) as f64;
                    c0 += 1;
                } else {
                    m1 += x.at(i, j) as f64;
                    c1 += 1;
                }
            }
            assert!((m0 / c0 as f64).abs() < 0.15);
            assert!((m1 / c1 as f64).abs() < 0.15);
        }
    }

    #[test]
    fn split_dataset_disjoint_and_normalized() {
        let mut rng = Rng::new(3);
        let (x, y) = ring(&mut rng, 4, 300, 0.1);
        let ds = split_dataset("t", x, y, 2, 200, &mut rng);
        assert_eq!(ds.train_x.rows, 200);
        assert_eq!(ds.test_x.rows, 100);
        let mu = ds.train_x.col_means();
        assert!(mu.iter().all(|m| m.abs() < 1e-4));
    }
}
