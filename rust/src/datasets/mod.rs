//! Datasets: synthetic substitutes for the paper's benchmarks.
//!
//! No network access exists in the build environment, so the six UCI
//! benchmarks of Fig. 2 are replaced by generators matched to each
//! dataset's published statistics (dimension, class count, sample counts —
//! Supp. Table III), with nonlinear class structure so that kernel methods
//! outperform linear ones (DESIGN.md §Substitutions). If real CSVs are
//! placed under `data/`, `loader` will use them instead.

pub mod loader;
pub mod lra;
pub mod synth;
pub mod uci;

pub use synth::Dataset;
pub use uci::{load_uci, UciName, ALL_UCI};
