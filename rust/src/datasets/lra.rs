//! LRA-lite long-sequence tasks, mirroring `python/compile/data.py` for
//! serving-time request replay and load generation. The Rust generators
//! use the same construction but an independent RNG: the coordinator
//! normally replays the exact held-out set exported by the Python side
//! (`testset_<task>.npz`); these generators feed load tests and ablations.

use crate::util::Rng;

pub const PATTERN_VOCAB: usize = 16;
pub const LISTOPS_VOCAB: usize = 18;

/// A batch of token sequences with labels.
#[derive(Clone, Debug)]
pub struct SeqBatch {
    pub tokens: Vec<i32>, // n x seq_len row-major
    pub labels: Vec<usize>,
    pub n: usize,
    pub seq_len: usize,
}

impl SeqBatch {
    pub fn row(&self, i: usize) -> &[i32] {
        &self.tokens[i * self.seq_len..(i + 1) * self.seq_len]
    }
}

/// Long-range retrieval task (`pattern`): one marker token (id 1) in the
/// last two thirds, followed by a payload in [3, 9]; label = payload
/// parity.
pub fn gen_pattern(rng: &mut Rng, n: usize, seq_len: usize) -> SeqBatch {
    assert!(seq_len >= 8);
    let mut tokens = vec![0i32; n * seq_len];
    let mut labels = Vec::with_capacity(n);
    let third = seq_len / 3;
    for i in 0..n {
        let row = &mut tokens[i * seq_len..(i + 1) * seq_len];
        for t in row.iter_mut() {
            *t = (10 + rng.below(PATTERN_VOCAB - 10)) as i32;
        }
        let pos = third + rng.below(seq_len - 1 - third);
        let payload = 3 + rng.below(7);
        row[pos] = 1;
        row[pos + 1] = payload as i32;
        labels.push((payload - 3) % 2);
    }
    SeqBatch { tokens, labels, n, seq_len }
}

const OP_MAX: i32 = 11;
const OP_MIN: i32 = 12;
const OP_MED: i32 = 13;
const OP_SM: i32 = 14;
const LPAR: i32 = 15;
const RPAR: i32 = 16;

fn gen_expr(rng: &mut Rng, depth: usize, max_args: usize, out: &mut Vec<i32>) -> usize {
    if depth == 0 || rng.f64() < 0.35 {
        let v = rng.below(10);
        out.push(1 + v as i32);
        return v;
    }
    let op = [OP_MAX, OP_MIN, OP_MED, OP_SM][rng.below(4)];
    let n_args = 2 + rng.below(max_args - 1);
    out.push(LPAR);
    out.push(op);
    let mut vals = Vec::with_capacity(n_args);
    for _ in 0..n_args {
        vals.push(gen_expr(rng, depth - 1, max_args, out));
    }
    out.push(RPAR);
    match op {
        OP_MAX => *vals.iter().max().unwrap(),
        OP_MIN => *vals.iter().min().unwrap(),
        OP_MED => {
            let mut s = vals.clone();
            s.sort_unstable();
            s[s.len() / 2]
        }
        _ => vals.iter().sum::<usize>() % 10,
    }
}

/// ListOps-lite: prefix-notation expressions, label = evaluated digit.
pub fn gen_listops(rng: &mut Rng, n: usize, seq_len: usize) -> SeqBatch {
    let mut tokens = vec![0i32; n * seq_len];
    let mut labels = Vec::with_capacity(n);
    let mut i = 0;
    let mut expr = Vec::new();
    while i < n {
        expr.clear();
        let v = gen_expr(rng, 3, 4, &mut expr);
        if expr.len() > seq_len {
            continue;
        }
        let row = &mut tokens[i * seq_len..(i + 1) * seq_len];
        row[..expr.len()].copy_from_slice(&expr);
        labels.push(v);
        i += 1;
    }
    SeqBatch { tokens, labels, n, seq_len }
}

/// Evaluate a listops token sequence (oracle used by tests).
pub fn eval_listops(tokens: &[i32]) -> Option<usize> {
    let toks: Vec<i32> = tokens.iter().copied().filter(|&t| t != 0).collect();
    let mut pos = 0usize;
    fn parse(toks: &[i32], pos: &mut usize) -> Option<usize> {
        let t = *toks.get(*pos)?;
        if (1..=10).contains(&t) {
            *pos += 1;
            return Some((t - 1) as usize);
        }
        if t != LPAR {
            return None;
        }
        *pos += 1;
        let op = *toks.get(*pos)?;
        *pos += 1;
        let mut vals = Vec::new();
        while *toks.get(*pos)? != RPAR {
            vals.push(parse(toks, pos)?);
        }
        *pos += 1;
        Some(match op {
            OP_MAX => *vals.iter().max()?,
            OP_MIN => *vals.iter().min()?,
            OP_MED => {
                let mut s = vals.clone();
                s.sort_unstable();
                s[s.len() / 2]
            }
            OP_SM => vals.iter().sum::<usize>() % 10,
            _ => return None,
        })
    }
    parse(&toks, &mut pos)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pattern_structure() {
        let mut rng = Rng::new(0);
        let b = gen_pattern(&mut rng, 128, 64);
        for i in 0..b.n {
            let row = b.row(i);
            let pos = row.iter().position(|&t| t == 1).unwrap();
            assert!(pos >= 64 / 3);
            let payload = row[pos + 1] as usize;
            assert!((3..=9).contains(&payload));
            assert_eq!(b.labels[i], (payload - 3) % 2);
        }
    }

    #[test]
    fn listops_labels_match_oracle() {
        let mut rng = Rng::new(1);
        let b = gen_listops(&mut rng, 64, 128);
        for i in 0..b.n {
            assert_eq!(eval_listops(b.row(i)), Some(b.labels[i]), "row {i}");
        }
    }

    #[test]
    fn listops_labels_in_range() {
        let mut rng = Rng::new(2);
        let b = gen_listops(&mut rng, 64, 96);
        assert!(b.labels.iter().all(|&l| l < 10));
    }

    #[test]
    fn deterministic() {
        let a = gen_pattern(&mut Rng::new(5), 16, 32);
        let b = gen_pattern(&mut Rng::new(5), 16, 32);
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.labels, b.labels);
    }
}
