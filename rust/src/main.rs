//! `imka` — leader entrypoint and CLI.
//!
//! Subcommands:
//!   serve                 start the coordinator + TCP server
//!   experiment <id>       regenerate a paper table/figure (see `help`)
//!   program-demo          program a matrix on the simulated chip, report
//!                         GDP convergence + MVM error
//!   info                  artifact registry + chip + model summary
//!   help

use imka::cli::Args;
use imka::config::Config;
use imka::coordinator::{Engine, Server};
use imka::error::Result;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    let config_path = args.get("config").map(std::path::Path::new);
    let mut cfg = Config::load_or_default(config_path)?;
    if let Some(dir) = args.get("artifacts") {
        cfg.artifacts_dir = dir.to_string();
    }

    match args.subcommand.as_str() {
        "" | "help" => {
            print_help();
            Ok(())
        }
        "serve" => serve(args, &cfg),
        "experiment" => {
            let id = args
                .positional
                .first()
                .map(|s| s.as_str())
                .unwrap_or("all");
            imka::experiments::run(id, args)
        }
        "program-demo" => program_demo(args, &cfg),
        "info" => info(&cfg),
        other => {
            eprintln!("unknown subcommand '{other}'");
            print_help();
            std::process::exit(2);
        }
    }
}

fn print_help() {
    println!(
        r#"imka — In-Memory Kernel Approximation (paper reproduction)

USAGE: imka <subcommand> [options]

SUBCOMMANDS
  serve                        boot the coordinator and TCP server
      --bind ADDR              (default 127.0.0.1:7473)
      --workers N --max-batch N --max-wait-us N --replication N
      --drain-cap N            batcher opportunistic-drain cap per pass
                               (0 = auto, 4 x max-batch)
      --wire MODE              wire protocol: auto (per-request detection,
                               default) | json | binary (see docs/protocol.md)
      --max-frame-bytes N      cap on a binary frame body / JSON request
                               line (default 16777216)
      --idle-timeout-s S       close connections idle (or mid-request)
                               longer than S seconds (default 900)
      --attn-heads N --attn-d-head N --attn-m N
                               streaming-attention lane geometry
                               (per-head FAVOR+ Ω programmed on the fleet)
      --attn-max-sessions N    concurrently open attention sessions
      --attn-path P            default attn_open path: analog | fp32
      --n-chips N              emulated chips in the fleet (default 1)
      --placement P            packed | sharded
      --router R               round_robin | least_loaded | p2c
      --fleet-replication N    chip-level replicas per lane shard
      --recal-interval-s S     drift recalibration pass period (0 = off)
      --drift-err-budget E     estimated drift error that triggers recal
      --control                run the fleet control plane (health probes,
                               chip eviction + shard re-placement, draining)
      --control-interval-s S   control tick period (default 1.0)
      --autoscale              queue-driven fleet autoscaling (implies --control)
      --min-chips N --max-chips N
                               autoscaler fleet-size bounds
      --scale-up-depth F       mean queue depth per chip that adds a chip
      --scale-down-depth F     mean queue depth per chip that drains one
      --replace-per-tick N     deferred eviction re-placements (shard GDP
                               rewrites) drained per control tick
      --chip-cores LIST        per-chip core counts for heterogeneous
                               fleets, e.g. 64,32,64
      --trace-sample-every N   record a trace span for 1 in N requests
                               (0 = off, 1 = every request; default 8)
      --trace-buffer N         sampled spans kept for the trace verb
      --dispatch-force M       substrate routing for analog-eligible
                               batches: auto (cost model, default) |
                               analog | digital (see docs/dispatch.md)
      --analog-min-batch N     smallest batch the cost model may route
                               to the analog fleet (default 4)
  experiment <id>              regenerate a paper table/figure:
      fig2a fig2b fig3b table1 supp20 supp21 supp8 supp-table2
      redraw ablate-relu ablate-replication ablate-noise all
      common flags: --seeds N --scale F --n-eval N --per-dataset
  program-demo                 GDP program-and-verify walkthrough
      --rows N --cols N
  info                         artifacts + chip + model summary

GLOBAL
  --artifacts DIR              (default ./artifacts; or IMKA_ARTIFACTS_DIR)
  --config FILE                TOML config (chip noise, serving)
"#
    );
}

fn serve(args: &Args, cfg: &Config) -> Result<()> {
    use imka::error::Error;
    use imka::fleet::{PlacementPolicy, RouterPolicy};

    let mut cfg = cfg.clone();
    if let Some(bind) = args.get("bind") {
        cfg.serve.bind = bind.to_string();
    }
    cfg.serve.workers = args.usize_or("workers", cfg.serve.workers)?;
    cfg.serve.max_batch = args.usize_or("max-batch", cfg.serve.max_batch)?;
    cfg.serve.max_wait_us = args.usize_or("max-wait-us", cfg.serve.max_wait_us as usize)? as u64;
    cfg.serve.replication = args.usize_or("replication", cfg.serve.replication)?;
    cfg.serve.drain_cap = args.usize_or("drain-cap", cfg.serve.drain_cap)?;
    if let Some(w) = args.get("wire") {
        imka::wire::WireMode::parse(w)
            .ok_or_else(|| Error::Parse(format!("--wire: unknown mode '{w}' (auto | json | binary)")))?;
        cfg.serve.wire = w.to_string();
    }
    cfg.serve.max_frame_bytes =
        args.usize_or("max-frame-bytes", cfg.serve.max_frame_bytes)?.max(1);
    cfg.serve.idle_timeout_s = args.f64_or("idle-timeout-s", cfg.serve.idle_timeout_s)?;
    cfg.attention.serve.heads = args.usize_or("attn-heads", cfg.attention.serve.heads)?.max(1);
    cfg.attention.serve.d_head =
        args.usize_or("attn-d-head", cfg.attention.serve.d_head)?.max(1);
    cfg.attention.serve.m = args.usize_or("attn-m", cfg.attention.serve.m)?.max(1);
    cfg.attention.serve.max_sessions = args
        .usize_or("attn-max-sessions", cfg.attention.serve.max_sessions)?
        .max(1);
    if let Some(p) = args.get("attn-path") {
        imka::coordinator::PathKind::parse(p)
            .ok_or_else(|| Error::Parse(format!("--attn-path: unknown path '{p}'")))?;
        cfg.attention.serve.path = p.to_string();
    }
    cfg.fleet.n_chips = args.usize_or("n-chips", cfg.fleet.n_chips)?.max(1);
    cfg.fleet.replication = args.usize_or("fleet-replication", cfg.fleet.replication)?.max(1);
    cfg.fleet.recal_interval_s = args.f64_or("recal-interval-s", cfg.fleet.recal_interval_s)?;
    cfg.fleet.drift_err_budget = args.f64_or("drift-err-budget", cfg.fleet.drift_err_budget)?;
    if let Some(p) = args.get("placement") {
        cfg.fleet.placement = PlacementPolicy::parse(p)
            .ok_or_else(|| Error::Parse(format!("--placement: unknown policy '{p}'")))?;
    }
    if let Some(r) = args.get("router") {
        cfg.fleet.router = RouterPolicy::parse(r)
            .ok_or_else(|| Error::Parse(format!("--router: unknown policy '{r}'")))?;
    }
    // control plane (autoscaling needs the control loop to observe)
    cfg.fleet.control.enabled =
        cfg.fleet.control.enabled || args.bool("control") || args.bool("autoscale");
    cfg.fleet.control.autoscale = cfg.fleet.control.autoscale || args.bool("autoscale");
    cfg.fleet.control.interval_s =
        args.f64_or("control-interval-s", cfg.fleet.control.interval_s)?;
    cfg.fleet.control.min_chips =
        args.usize_or("min-chips", cfg.fleet.control.min_chips)?.max(1);
    cfg.fleet.control.max_chips =
        args.usize_or("max-chips", cfg.fleet.control.max_chips)?.max(1);
    cfg.fleet.control.scale_up_depth =
        args.f64_or("scale-up-depth", cfg.fleet.control.scale_up_depth)?;
    cfg.fleet.control.scale_down_depth =
        args.f64_or("scale-down-depth", cfg.fleet.control.scale_down_depth)?;
    cfg.fleet.control.replace_per_tick =
        args.usize_or("replace-per-tick", cfg.fleet.control.replace_per_tick)?.max(1);
    if let Some(list) = args.get("chip-cores") {
        cfg.fleet.chip_cores = list
            .split(',')
            .map(|p| {
                p.trim().parse::<usize>().map_err(|_| {
                    Error::Parse(format!("--chip-cores expects integers, got '{p}'"))
                })
            })
            .collect::<Result<Vec<_>>>()?;
    }
    cfg.obsv.trace_sample_every =
        args.usize_or("trace-sample-every", cfg.obsv.trace_sample_every as usize)? as u64;
    cfg.obsv.trace_buffer = args.usize_or("trace-buffer", cfg.obsv.trace_buffer)?.max(1);
    if let Some(f) = args.get("dispatch-force") {
        imka::fleet::ForceMode::parse(f).ok_or_else(|| {
            Error::Parse(format!("--dispatch-force: unknown mode '{f}' (auto | analog | digital)"))
        })?;
        cfg.dispatch.force = f.to_string();
    }
    cfg.dispatch.analog_min_batch =
        args.usize_or("analog-min-batch", cfg.dispatch.analog_min_batch)?.max(1);

    println!("booting engine (artifacts: {})...", cfg.artifacts_dir);
    let engine = Engine::start(&cfg)?;
    println!(
        "engine up: {} chips ({} placement, {} router), {} cores programmed \
         ({:.1}% of fleet), model loaded: {}",
        engine.n_chips(),
        cfg.fleet.placement.as_str(),
        cfg.fleet.router.as_str(),
        engine.cores_used(),
        100.0 * engine.fleet_utilization(),
        engine.has_model()
    );
    {
        let a = &cfg.attention.serve;
        println!(
            "attention serving: {} heads x d_head {} x m {} (default path {}, \
             up to {} sessions)",
            a.heads, a.d_head, a.m, a.path, a.max_sessions
        );
    }
    println!(
        "hybrid dispatch: force={}, analog floor {} rows (cost-model \
         routing per batch; imka_dispatch_* metrics)",
        cfg.dispatch.force, cfg.dispatch.analog_min_batch
    );
    if cfg.obsv.trace_sample_every > 0 {
        println!(
            "tracing: 1 in {} requests sampled, newest {} spans kept (trace verb)",
            cfg.obsv.trace_sample_every, cfg.obsv.trace_buffer
        );
    }
    if cfg.fleet.recal_interval_s > 0.0 {
        match imka::fleet::age_at_budget(&cfg.chip, cfg.fleet.drift_err_budget) {
            Some(age) => println!(
                "drift recal: every {:.0}s, chips reprogram at age ~{age:.0}s \
                 (budget {:.3})",
                cfg.fleet.recal_interval_s, cfg.fleet.drift_err_budget
            ),
            None => println!("drift recal: enabled, but this chip model never drifts"),
        }
    }
    if cfg.fleet.control.enabled {
        let c = &cfg.fleet.control;
        println!(
            "control plane: tick {:.2}s, evict after {} dead probes{}",
            c.interval_s,
            c.probe_evict_after,
            if c.autoscale {
                format!(
                    ", autoscale {}..{} chips (up >{:.1}, down <{:.1} in-flight/chip)",
                    c.min_chips, c.max_chips, c.scale_up_depth, c.scale_down_depth
                )
            } else {
                String::new()
            }
        );
    }
    let server = Server::start(engine, &cfg.serve.bind)?;
    let wire_desc = match cfg.serve.wire.as_str() {
        "json" => "newline-JSON only",
        "binary" => "binary frames only",
        _ => "newline-JSON + binary frames, auto-detected",
    };
    println!(
        "listening on {} ({wire_desc}; max frame {} bytes, idle timeout {:.0}s; \
         Ctrl-C to stop)",
        server.addr, cfg.serve.max_frame_bytes, cfg.serve.idle_timeout_s
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn program_demo(args: &Args, cfg: &Config) -> Result<()> {
    use imka::aimc::Chip;
    use imka::linalg::Mat;
    use imka::util::Rng;

    let rows = args.usize_or("rows", 64)?;
    let cols = args.usize_or("cols", 128)?;
    let mut rng = Rng::new(42);
    let w = Mat::randn(rows, cols, &mut rng);
    let x_cal = Mat::randn(128, rows, &mut rng);

    println!(
        "programming a {rows}x{cols} matrix onto the simulated chip \
         ({} GDP iterations, sigma_prog {:.3})",
        cfg.chip.program_iters, cfg.chip.sigma_prog
    );
    let mut chip = Chip::new(cfg.chip.clone(), 7);
    let h = chip.program_matrix("demo", &w, &x_cal, 1)?;
    for (i, s) in chip.program_stats(&h).unwrap().iter().enumerate() {
        println!(
            "  tile {i}: rms weight error {:.4} -> {:.4} ({} iters)",
            s.rms_initial, s.rms_final, s.iters
        );
    }
    let x = Mat::randn(32, rows, &mut rng);
    let y = chip.matmul(&h, &x)?;
    let want = imka::linalg::matmul(&x, &w);
    println!(
        "  analog MVM relative error: {:.4} (32x{rows} batch)",
        imka::util::stats::rel_fro_error(&y.data, &want.data)
    );
    println!("  chip utilization: {:.1}%", 100.0 * chip.utilization());
    Ok(())
}

fn info(cfg: &Config) -> Result<()> {
    use imka::runtime::Registry;
    println!(
        "chip: {} cores x {}x{} ({} weights capacity)",
        cfg.chip.cores,
        cfg.chip.rows,
        cfg.chip.cols,
        cfg.chip.capacity()
    );
    println!(
        "fleet: {} chips, placement {}, router {}, replication {}, \
         recal every {}s at budget {:.3}",
        cfg.fleet.n_chips,
        cfg.fleet.placement.as_str(),
        cfg.fleet.router.as_str(),
        cfg.fleet.replication,
        cfg.fleet.recal_interval_s,
        cfg.fleet.drift_err_budget
    );
    println!(
        "noise: sigma_prog {:.3}, sigma_read {:.3}, drift nu {:.3}±{:.3} @ t={}s (comp: {})",
        cfg.chip.sigma_prog,
        cfg.chip.sigma_read,
        cfg.chip.drift_nu_mean,
        cfg.chip.drift_nu_std,
        cfg.chip.drift_t_seconds,
        cfg.chip.drift_compensation
    );
    match Registry::open(std::path::Path::new(&cfg.artifacts_dir)) {
        Ok(reg) => {
            println!("artifacts ({}):", reg.specs.len());
            let mut counts = std::collections::BTreeMap::new();
            for s in reg.specs.values() {
                *counts.entry(s.kind.clone()).or_insert(0usize) += 1;
            }
            for (kind, count) in counts {
                println!("  {kind}: {count}");
            }
            if let Some(mc) = reg.model_config() {
                println!("model config: {}", mc.to_string());
            }
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    Ok(())
}
