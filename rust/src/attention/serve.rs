//! Streaming kernelized-attention state — the serving-side form of the
//! FAVOR+ re-association ([`crate::features::favor`]).
//!
//! Linear attention admits O(1)-per-token sufficient statistics: after t
//! tokens, `S = Σ_{i≤t} φ(k_i) v_iᵀ` (Df × dv) and `z = Σ_{i≤t} φ(k_i)`
//! (Df), and the attention output for a query is `φ(q)ᵀS / (φ(q)ᵀz)`.
//! A session therefore streams token-by-token with per-head state that
//! never grows with context length — the property that makes kernelized
//! attention a serving workload rather than a batch experiment, with the
//! φ projections `u = x·Ω` running as analog MVMs on the fleet.
//!
//! This module owns the pure state math; the session registry and the
//! fleet-wired φ paths live in [`crate::coordinator::session`].

use crate::features::favor::positive_features;
use crate::linalg::Mat;

/// Running FAVOR+ state of one attention head.
#[derive(Clone)]
pub struct HeadState {
    /// (Df × dv) running feature–value outer-product sum Σ φ(k)vᵀ
    s: Mat,
    /// (Df) running feature sum Σ φ(k)
    z: Vec<f32>,
    /// tokens absorbed so far
    tokens: usize,
}

impl HeadState {
    /// Fresh state for feature dimension `df` and value dimension `dv`.
    pub fn new(df: usize, dv: usize) -> HeadState {
        HeadState { s: Mat::zeros(df, dv), z: vec![0.0; df], tokens: 0 }
    }

    pub fn tokens(&self) -> usize {
        self.tokens
    }

    /// Fold one token's key features φ(k) and value v into the state.
    pub fn absorb(&mut self, phi_k: &[f32], v: &[f32]) {
        debug_assert_eq!(phi_k.len(), self.z.len());
        debug_assert_eq!(v.len(), self.s.cols);
        for (i, &f) in phi_k.iter().enumerate() {
            self.z[i] += f;
            let row = self.s.row_mut(i);
            for (r, &vv) in row.iter_mut().zip(v) {
                *r += f * vv;
            }
        }
        self.tokens += 1;
    }

    /// Attention output for query features φ(q) against the current
    /// state: `φ(q)ᵀS / max(φ(q)ᵀz, ε)` — identical normalization to the
    /// offline [`crate::features::favor::linear_attention_from_features`].
    pub fn attend(&self, phi_q: &[f32]) -> Vec<f32> {
        debug_assert_eq!(phi_q.len(), self.z.len());
        let mut num = vec![0.0f32; self.s.cols];
        let mut den = 0.0f32;
        for (i, &f) in phi_q.iter().enumerate() {
            if f == 0.0 {
                continue;
            }
            den += f * self.z[i];
            let row = self.s.row(i);
            for (n, &r) in num.iter_mut().zip(row) {
                *n += f * r;
            }
        }
        let den = den.max(1e-9);
        for n in &mut num {
            *n /= den;
        }
        num
    }
}

/// Reference: causal FAVOR+ attention for a whole sequence at once — row
/// t attends over tokens 0..=t. This is exactly what a streamed session
/// produces token-by-token, so tests pin the streaming path against it
/// (and against per-prefix [`crate::features::favor::favor_attention`],
/// whose last row it matches).
pub fn causal_favor_attention(q: &Mat, k: &Mat, v: &Mat, omega: &Mat) -> Mat {
    assert_eq!(q.rows, k.rows);
    assert_eq!(k.rows, v.rows);
    let scale = (q.cols as f32).powf(-0.25);
    let mut qs = q.clone();
    qs.scale(scale);
    let mut ks = k.clone();
    ks.scale(scale);
    let qp = positive_features(&qs, omega);
    let kp = positive_features(&ks, omega);
    let mut state = HeadState::new(qp.cols, v.cols);
    let mut out = Mat::zeros(q.rows, v.cols);
    for t in 0..q.rows {
        state.absorb(kp.row(t), v.row(t));
        out.row_mut(t).copy_from_slice(&state.attend(qp.row(t)));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::favor::favor_attention;
    use crate::features::{sample_omega, Sampler};
    use crate::util::stats::rel_fro_error;
    use crate::util::Rng;

    fn qkv(seed: u64, l: usize, d: usize) -> (Mat, Mat, Mat) {
        let mut rng = Rng::new(seed);
        let mut q = Mat::randn(l, d, &mut rng);
        q.scale(0.5);
        let mut k = Mat::randn(l, d, &mut rng);
        k.scale(0.5);
        let v = Mat::randn(l, d, &mut rng);
        (q, k, v)
    }

    #[test]
    fn causal_last_row_matches_offline_favor() {
        // the final token of a causal stream has seen the whole sequence,
        // so it must agree with full (non-causal) FAVOR+ attention's last
        // row to float-summation-order tolerance
        let (q, k, v) = qkv(0, 20, 8);
        let mut rng = Rng::new(1);
        let omega = sample_omega(Sampler::Orf, 8, 64, &mut rng);
        let causal = causal_favor_attention(&q, &k, &v, &omega);
        let full = favor_attention(&q, &k, &v, &omega);
        let last = q.rows - 1;
        let rel = rel_fro_error(causal.row(last), full.row(last));
        assert!(rel < 1e-4, "last-row rel {rel}");
    }

    #[test]
    fn every_prefix_matches_offline_favor_on_that_prefix() {
        // streamed output at step t == offline favor on tokens 0..=t,
        // last row — the acceptance identity for streamed sessions
        let (q, k, v) = qkv(2, 12, 8);
        let mut rng = Rng::new(3);
        let omega = sample_omega(Sampler::Orf, 8, 32, &mut rng);
        let causal = causal_favor_attention(&q, &k, &v, &omega);
        for t in [0usize, 3, 7, 11] {
            let idx: Vec<usize> = (0..=t).collect();
            let (qp, kp, vp) = (q.select_rows(&idx), k.select_rows(&idx), v.select_rows(&idx));
            let offline = favor_attention(&qp, &kp, &vp, &omega);
            let rel = rel_fro_error(causal.row(t), offline.row(t));
            assert!(rel < 1e-4, "prefix {t}: rel {rel}");
        }
    }

    #[test]
    fn state_is_order_insensitive_for_keys() {
        // S and z are sums: absorbing keys in any order yields the same
        // state (the property that makes replica retries harmless)
        let (_, k, v) = qkv(4, 6, 4);
        let mut rng = Rng::new(5);
        let omega = sample_omega(Sampler::Orf, 4, 16, &mut rng);
        let kp = positive_features(&k, &omega);
        let mut fwd = HeadState::new(kp.cols, v.cols);
        let mut rev = HeadState::new(kp.cols, v.cols);
        for t in 0..k.rows {
            fwd.absorb(kp.row(t), v.row(t));
            rev.absorb(kp.row(k.rows - 1 - t), v.row(k.rows - 1 - t));
        }
        let phi_q = kp.row(0);
        let a = fwd.attend(phi_q);
        let b = rev.attend(phi_q);
        let rel = rel_fro_error(&a, &b);
        assert!(rel < 1e-5, "rel {rel}");
        assert_eq!(fwd.tokens(), 6);
    }

    #[test]
    fn empty_state_attends_to_zero() {
        let state = HeadState::new(8, 4);
        let y = state.attend(&[0.5; 8]);
        assert_eq!(y.len(), 4);
        assert!(y.iter().all(|&v| v == 0.0));
        assert_eq!(state.tokens(), 0);
    }
}
