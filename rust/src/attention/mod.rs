//! Kernelized attention measurement machinery (Fig. 3b / Table I inputs).
//!
//! The attention math itself lives in [`crate::features::favor`]; this
//! module adds the analog-vs-digital comparison harness: projecting Q/K
//! through the chip simulator (or emulator) instead of a digital matmul
//! and quantifying the induced attention-matrix error — exactly the
//! isolated-error experiment of Fig. 3b. [`serve`] carries the same math
//! onto the serving path: per-session FAVOR+ running sums that stream
//! tokens with O(1) state (see `coordinator::session` for the fleet
//! wiring).

pub mod serve;

use crate::aimc::Emulator;
use crate::config::ChipConfig;
use crate::error::Result;
use crate::features::favor::{
    attention_matrix_from_features, exact_attention_matrix, positive_features,
};
use crate::features::maps::postprocess;
use crate::kernels::Kernel;
use crate::linalg::Mat;
use crate::util::Rng;

pub use crate::features::favor::{
    exact_attention, favor_attention, linear_attention_from_features,
};
pub use serve::{causal_favor_attention, HeadState};

/// Where the feature projection u = x·Ω runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Projection {
    /// FP-32 digital matmul
    Fp32,
    /// simulated AIMC chip (emulated mode)
    Analog,
}

/// Attention-matrix approximation error of FAVOR+ with the projection on
/// the chosen path, vs the exact softmax attention matrix.
///
/// q, k: (L x d_head) extracted head projections. Returns the relative
/// Frobenius error (the Fig. 3b metric).
pub fn attention_matrix_error(
    q: &Mat,
    k: &Mat,
    omega: &Mat,
    proj: Projection,
    chip_cfg: &ChipConfig,
    rng: &mut Rng,
) -> Result<f64> {
    let exact = exact_attention_matrix(q, k);
    let scale = (q.cols as f32).powf(-0.25);
    let mut qs = q.clone();
    qs.scale(scale);
    let mut ks = k.clone();
    ks.scale(scale);

    let (qp, kp) = match proj {
        Projection::Fp32 => (positive_features(&qs, omega), positive_features(&ks, omega)),
        Projection::Analog => {
            // program Ω once; both Q and K reads go through the same
            // noisy weights (as on the real chip)
            let mut em = Emulator::program(omega, chip_cfg, rng);
            let uq = em.forward(&qs);
            let uk = em.forward(&ks);
            (
                postprocess(Kernel::Softmax, &uq, Some(&qs)),
                postprocess(Kernel::Softmax, &uk, Some(&ks)),
            )
        }
    };
    let approx = attention_matrix_from_features(&qp, &kp);
    Ok(crate::util::stats::rel_fro_error(&approx.data, &exact.data))
}

/// Attention *output* error (D⁻¹Q'(K')ᵀV vs exact), same protocol.
pub fn attention_output_error(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    omega: &Mat,
    proj: Projection,
    chip_cfg: &ChipConfig,
    rng: &mut Rng,
) -> Result<f64> {
    let exact = exact_attention(q, k, v);
    let scale = (q.cols as f32).powf(-0.25);
    let mut qs = q.clone();
    qs.scale(scale);
    let mut ks = k.clone();
    ks.scale(scale);
    let (qp, kp) = match proj {
        Projection::Fp32 => (positive_features(&qs, omega), positive_features(&ks, omega)),
        Projection::Analog => {
            let mut em = Emulator::program(omega, chip_cfg, rng);
            let uq = em.forward(&qs);
            let uk = em.forward(&ks);
            (
                postprocess(Kernel::Softmax, &uq, Some(&qs)),
                postprocess(Kernel::Softmax, &uk, Some(&ks)),
            )
        }
    };
    let approx = linear_attention_from_features(&qp, &kp, v);
    Ok(crate::util::stats::rel_fro_error(&approx.data, &exact.data))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::{sample_omega, Sampler};

    fn qkv(seed: u64, l: usize, d: usize) -> (Mat, Mat, Mat) {
        let mut rng = Rng::new(seed);
        let mut q = Mat::randn(l, d, &mut rng);
        q.scale(0.5);
        let mut k = Mat::randn(l, d, &mut rng);
        k.scale(0.5);
        let v = Mat::randn(l, d, &mut rng);
        (q, k, v)
    }

    #[test]
    fn analog_error_slightly_above_fp32() {
        // the Fig. 3b claim: analog noise raises the error, but the gap
        // stays bounded
        let (q, k, _) = qkv(0, 48, 8);
        let cfg = ChipConfig::default();
        let mut e_fp = 0.0;
        let mut e_hw = 0.0;
        for s in 0..8u64 {
            let mut rng = Rng::new(100 + s);
            let omega = sample_omega(Sampler::Orf, 8, 128, &mut rng);
            e_fp += attention_matrix_error(&q, &k, &omega, Projection::Fp32, &cfg, &mut rng)
                .unwrap();
            e_hw += attention_matrix_error(&q, &k, &omega, Projection::Analog, &cfg, &mut rng)
                .unwrap();
        }
        e_fp /= 8.0;
        e_hw /= 8.0;
        assert!(e_hw > e_fp, "hw {e_hw} fp {e_fp}");
        assert!(e_hw < e_fp + 0.2, "gap too large: hw {e_hw} fp {e_fp}");
    }

    #[test]
    fn error_decreases_with_m_both_paths() {
        let (q, k, _) = qkv(1, 32, 8);
        let cfg = ChipConfig::default();
        for proj in [Projection::Fp32, Projection::Analog] {
            let err_at = |m: usize| {
                let mut acc = 0.0;
                for s in 0..5u64 {
                    let mut rng = Rng::new(200 + s);
                    let omega = sample_omega(Sampler::Orf, 8, m, &mut rng);
                    acc += attention_matrix_error(&q, &k, &omega, proj, &cfg, &mut rng).unwrap();
                }
                acc / 5.0
            };
            let e_small = err_at(16);
            let e_big = err_at(256);
            assert!(e_big < e_small, "{proj:?}: {e_big} vs {e_small}");
        }
    }

    #[test]
    fn output_error_finite_and_small_at_high_m() {
        let (q, k, v) = qkv(2, 24, 8);
        let cfg = ChipConfig::default();
        let mut rng = Rng::new(3);
        let omega = sample_omega(Sampler::Orf, 8, 512, &mut rng);
        let e = attention_output_error(&q, &k, &v, &omega, Projection::Analog, &cfg, &mut rng)
            .unwrap();
        assert!(e.is_finite() && e < 0.6, "e {e}");
    }
}
