//! Analytical energy/latency model (Supp. Note 4 / Supp. Tables II & VIII)
//! plus FLOP accounting for the pipeline stages.

pub mod device;
pub mod flops;

pub use device::{Device, DeviceSpec, ALL_DEVICES};
pub use flops::{mapping_ops, InferenceCost};

/// Latency (ms) and energy (mJ) of a workload of `ops` operations on a
/// device at peak throughput — the paper's own assumption for Supp.
/// Table VIII ("we omit post-processing and focus solely on the mapping").
pub fn latency_energy(ops: f64, dev: &DeviceSpec) -> (f64, f64) {
    let latency_s = ops / dev.tops / 1e12;
    let energy_j = latency_s * dev.power_w;
    (latency_s * 1e3, energy_j * 1e3)
}

/// Modelled energy (µJ) of mapping an `l`×`d` batch through a `d`×`m` Ω
/// on `dev` — the per-substrate energy column of the dispatch cost model
/// and the serving responses' `energy_uj` field (µJ = mJ × 1e3).
pub fn mapping_energy_uj(l: usize, d: usize, m: usize, dev: &DeviceSpec) -> f64 {
    let (_, e_mj) = latency_energy(mapping_ops(l, d, m), dev);
    e_mj * 1e3
}

/// Effective AIMC throughput when only `cores_used` of `cores_total`
/// crossbars hold the mapping (the under-utilization discussion of Supp.
/// Note 4); replication multiplies the utilized cores.
pub fn aimc_effective_tops(peak_tops: f64, cores_used: usize, cores_total: usize) -> f64 {
    peak_tops * (cores_used.min(cores_total) as f64) / cores_total as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use device::Device;
    use flops::mapping_ops;

    #[test]
    fn supp_table_viii_row1_reproduced() {
        // L = 1024, d = 512, m = 1024 -> paper: AIMC 0.0170 ms / 0.1100 mJ,
        // GPU INT8 0.0017 ms / 0.6883 mJ, CPU 0.8738 ms / 221.0748 mJ
        let ops = mapping_ops(1024, 512, 1024);
        let (l, e) = latency_energy(ops, &Device::Aimc.spec());
        assert!((l - 0.0170).abs() < 0.0005, "aimc latency {l}");
        assert!((e - 0.1100).abs() < 0.005, "aimc energy {e}");
        let (l, e) = latency_energy(ops, &Device::GpuInt8.spec());
        assert!((l - 0.0017).abs() < 0.0002, "gpu8 latency {l}");
        assert!((e - 0.6883).abs() < 0.02, "gpu8 energy {e}");
        let (l, e) = latency_energy(ops, &Device::Cpu.spec());
        assert!((l - 0.8738).abs() < 0.01, "cpu latency {l}");
        assert!((e - 221.0748).abs() < 2.0, "cpu energy {e}");
    }

    #[test]
    fn supp_table_viii_row2_reproduced() {
        // L = 1024, d = 1024, m = 2048 -> AIMC 0.0681 ms / 0.4401 mJ,
        // GPU FP16 0.0138 ms / 5.5064 mJ
        let ops = mapping_ops(1024, 1024, 2048);
        let (l, e) = latency_energy(ops, &Device::Aimc.spec());
        assert!((l - 0.0681).abs() < 0.001, "aimc latency {l}");
        assert!((e - 0.4401).abs() < 0.01, "aimc energy {e}");
        let (l, e) = latency_energy(ops, &Device::GpuFp16.spec());
        assert!((l - 0.0138).abs() < 0.0005, "gpu16 latency {l}");
        assert!((e - 5.5064).abs() < 0.1, "gpu16 energy {e}");
    }

    #[test]
    fn aimc_energy_advantage_6_to_12x() {
        // the paper's headline: 6.2x-12.4x vs the A100
        let ops = mapping_ops(1024, 512, 1024);
        let (_, e_aimc) = latency_energy(ops, &Device::Aimc.spec());
        let (_, e_gpu8) = latency_energy(ops, &Device::GpuInt8.spec());
        let (_, e_gpu16) = latency_energy(ops, &Device::GpuFp16.spec());
        let r8 = e_gpu8 / e_aimc;
        let r16 = e_gpu16 / e_aimc;
        assert!(r8 > 6.0 && r8 < 6.6, "int8 ratio {r8}");
        assert!(r16 > 12.0 && r16 < 13.0, "fp16 ratio {r16}");
    }

    #[test]
    fn mapping_energy_uj_matches_latency_energy() {
        // Supp. Table VIII row 1: AIMC 0.1100 mJ -> 110 µJ
        let uj = mapping_energy_uj(1024, 512, 1024, &Device::Aimc.spec());
        assert!((uj - 110.0).abs() < 5.0, "aimc µJ {uj}");
        // the digital substrate pays orders of magnitude more per mapping,
        // which is what tilts the dispatch cost model analog at scale
        let cpu = mapping_energy_uj(1024, 512, 1024, &Device::Cpu.spec());
        assert!(cpu > 100.0 * uj, "cpu µJ {cpu} vs aimc {uj}");
        assert_eq!(mapping_energy_uj(0, 512, 1024, &Device::Cpu.spec()), 0.0);
    }

    #[test]
    fn under_utilization_scales_tops() {
        let t = aimc_effective_tops(63.1, 8, 64);
        assert!((t - 7.8875).abs() < 1e-3); // paper: 8 cores -> 7.8875 TOPS
        assert!((aimc_effective_tops(63.1, 64, 64) - 63.1).abs() < 1e-9);
        assert!((aimc_effective_tops(63.1, 200, 64) - 63.1).abs() < 1e-9);
    }
}
