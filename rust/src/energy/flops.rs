//! FLOP accounting (Supp. Table II and the Results-section counts).

/// Operations of the mapping x (L x d) @ Ω (d x m): 2·L·d·m
/// (the paper's Supp. Table VIII counts multiply+add as 2 ops).
pub fn mapping_ops(l: usize, d: usize, m: usize) -> f64 {
    2.0 * l as f64 * d as f64 * m as f64
}

/// Inference-FLOPs per sample for each technique of Supp. Table II.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InferenceCost {
    /// explicit high-dimensional mapping φ(x)ᵀφ(y): 4·H·d + 2·H
    HighDimMapping { h: usize, d: usize },
    /// kernel methods k(x, ·) against N training samples: 2·d·N
    KernelMethod { d: usize, n: usize },
    /// digital kernel approximation z(x)ᵀw: 4·m·d + 2·D
    KernelApprox { m: usize, d: usize, cap_d: usize },
    /// AIMC deployment: mapping in-memory, only 2·D digital
    AimcDeployment { cap_d: usize },
}

impl InferenceCost {
    pub fn flops(&self) -> f64 {
        match *self {
            InferenceCost::HighDimMapping { h, d } => 4.0 * h as f64 * d as f64 + 2.0 * h as f64,
            InferenceCost::KernelMethod { d, n } => 2.0 * d as f64 * n as f64,
            InferenceCost::KernelApprox { m, d, cap_d } => {
                4.0 * m as f64 * d as f64 + 2.0 * cap_d as f64
            }
            InferenceCost::AimcDeployment { cap_d } => 2.0 * cap_d as f64,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            InferenceCost::HighDimMapping { .. } => "High-dimensional Mappings",
            InferenceCost::KernelMethod { .. } => "Kernel Methods",
            InferenceCost::KernelApprox { .. } => "Kernel Approximations",
            InferenceCost::AimcDeployment { .. } => "AIMC Deployment",
        }
    }
}

/// Digital-FLOP reduction of in-memory kernel approximation (Results §A):
/// from 8·a·d² + 4·l·a·d down to 4·l·a·d.
pub fn digital_flops_reduction(a: usize, d: usize, l: usize) -> (f64, f64) {
    let before = 8.0 * a as f64 * (d * d) as f64 + 4.0 * (l * a * d) as f64;
    let after = 4.0 * (l * a * d) as f64;
    (before, after)
}

/// Fraction of multi-head-attention FLOPs offloadable to AIMC under
/// FAVOR+ (Results §C: "if D = 2m, the mapping accounts for roughly one
/// third of the total FLOPs").
///
/// Linear attention per head: mapping 2·L·d·m (on-chip), digital
/// post-processing + Q'(K'V) re-association ≈ 2·L·D·d·2 with D = l·m.
pub fn attention_offload_fraction(l_seq: usize, d_head: usize, m: usize, l_fns: usize) -> f64 {
    let cap_d = l_fns * m;
    // two mappings (Q and K)
    let on_chip = 2.0 * mapping_ops(l_seq, d_head, m);
    // digital: K'ᵀV (2·L·D·dv) + Q'(K'ᵀV) (2·L·D·dv) + normalizer (≈2·L·D)
    let digital = 2.0 * 2.0 * l_seq as f64 * cap_d as f64 * d_head as f64
        + 2.0 * l_seq as f64 * cap_d as f64;
    on_chip / (on_chip + digital)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_ii_ordering_holds() {
        // the table is ordered by decreasing cost for representative sizes
        let d = 16;
        let n = 50_000;
        let h = 100_000; // Hilbert-space dim >> others
        let m = 512;
        let cap_d = 1024;
        let costs = [
            InferenceCost::HighDimMapping { h, d }.flops(),
            InferenceCost::KernelMethod { d, n }.flops(),
            InferenceCost::KernelApprox { m, d, cap_d }.flops(),
            InferenceCost::AimcDeployment { cap_d }.flops(),
        ];
        assert!(costs[0] > costs[1]);
        assert!(costs[1] > costs[2]);
        assert!(costs[2] > costs[3]);
    }

    #[test]
    fn aimc_cost_is_2d() {
        assert_eq!(InferenceCost::AimcDeployment { cap_d: 512 }.flops(), 1024.0);
    }

    #[test]
    fn digital_reduction_large() {
        // a=16, d=64, l=2: 8·16·4096 + 4·2·16·64 vs 4·2·16·64
        let (before, after) = digital_flops_reduction(16, 64, 2);
        assert!(before / after > 50.0);
        assert_eq!(after, 8192.0);
    }

    #[test]
    fn attention_offload_between_third_and_half() {
        // paper: "between half and one third of the FLOPs"
        let f = attention_offload_fraction(1024, 32, 4 * 32, 2);
        assert!(f > 0.15 && f < 0.55, "fraction {f}");
    }

    #[test]
    fn mapping_ops_formula() {
        assert_eq!(mapping_ops(1024, 512, 1024), 2.0 * 1024.0 * 512.0 * 1024.0);
    }
}
