//! Device constants from Supp. Note 4.

/// Throughput/power spec of one compute device.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceSpec {
    pub name: &'static str,
    /// peak tera-operations per second
    pub tops: f64,
    /// power at peak, watts
    pub power_w: f64,
    /// die area, mm² (Discussion: 144 vs 826)
    pub area_mm2: f64,
}

/// The compared devices.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Device {
    /// IBM HERMES Project Chip (the simulated substrate)
    Aimc,
    /// NVIDIA A100, INT8 tensor cores
    GpuInt8,
    /// NVIDIA A100, FP16 tensor cores
    GpuFp16,
    /// Intel i9-14900KF
    Cpu,
}

pub const ALL_DEVICES: [Device; 4] =
    [Device::Aimc, Device::GpuInt8, Device::GpuFp16, Device::Cpu];

impl Device {
    pub fn spec(&self) -> DeviceSpec {
        match self {
            Device::Aimc => DeviceSpec {
                name: "AIMC",
                tops: 63.1,
                power_w: 6.5,
                area_mm2: 144.0,
            },
            Device::GpuInt8 => DeviceSpec {
                name: "GPU INT8",
                tops: 624.0,
                power_w: 400.0,
                area_mm2: 826.0,
            },
            Device::GpuFp16 => DeviceSpec {
                name: "GPU FP16",
                tops: 312.0,
                power_w: 400.0,
                area_mm2: 826.0,
            },
            Device::Cpu => DeviceSpec {
                name: "CPU",
                tops: 1.2288,
                power_w: 253.0,
                area_mm2: 257.0,
            },
        }
    }

    /// TOPS per watt (paper: AIMC 9.76).
    pub fn tops_per_watt(&self) -> f64 {
        let s = self.spec();
        s.tops / s.power_w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aimc_efficiency_matches_paper() {
        // paper: "energy efficiency of 9.76 TOPS per Watt"
        assert!((Device::Aimc.tops_per_watt() - 9.707).abs() < 0.1);
    }

    #[test]
    fn gpu_throughput_ratio() {
        // paper: GPU MVM throughput ~9.9x the HERMES chip (INT8)
        let r = Device::GpuInt8.spec().tops / Device::Aimc.spec().tops;
        assert!((r - 9.9).abs() < 0.15, "ratio {r}");
    }

    #[test]
    fn footprint_ratio() {
        let r = Device::GpuInt8.spec().area_mm2 / Device::Aimc.spec().area_mm2;
        assert!(r > 5.0, "paper: 826 vs 144 mm²");
    }
}
