#!/usr/bin/env bash
# Tier-1 gate in one command: build, test, lint, format check.
#
#   scripts/ci.sh               # full gate
#   SKIP_FMT=1 scripts/ci.sh    # environments without rustfmt
#   SKIP_CLIPPY=1 scripts/ci.sh # environments without clippy
#
# Runs from any cwd. Benches and examples are compiled as part of
# `cargo test` (they are declared targets), so the gate also catches
# bit-rot there.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# placement/routing/failover smoke: a 2-chip fleet with a small lane runs
# the full bench (scaling rows + the contended same-chip row comparing
# the serialized pre-refactor lock discipline against core-parallel read
# locks + chaos eviction) in seconds, so fleet and core-concurrency
# regressions surface in the tier-1 gate even without artifacts
echo "== bench_fleet smoke (2-chip, small lane, contended row) =="
IMKA_BENCH_FLEET_SMOKE=1 cargo bench --bench bench_fleet

# streaming-attention smoke: both projection paths of the session layer
# (fp32 + analog over the fleet router), including the final-token
# rel-err check against offline favor_attention — artifact-free. The
# gate is the freshly-emitted BENCH_serve.json (per-connection
# throughput, append-latency percentiles, per-stage means) plus the
# metrics exposition tail, which must carry the core fleet gauges.
echo "== bench_attention_serve smoke (fp32 + analog sessions) =="
rm -f BENCH_serve.json
serve_log="$(mktemp)"
IMKA_BENCH_ATTN_SMOKE=1 cargo bench --bench bench_attention_serve | tee "$serve_log"
if [ ! -f BENCH_serve.json ]; then
    echo "serve smoke: BENCH_serve.json was not emitted" >&2
    exit 1
fi
if ! grep -q '"paths_with_zero_throughput":0' BENCH_serve.json; then
    echo "serve smoke: a projection path reported zero tokens/s" >&2
    exit 1
fi
for gauge in imka_chip_core_utilization imka_fleet_inflight imka_lane_latency_us; do
    if ! grep -q "$gauge" "$serve_log"; then
        echo "serve smoke: metrics exposition is missing $gauge" >&2
        exit 1
    fi
done
# reply encoding is a first-class pipeline stage now; its histogram must
# be registered in the exposition alongside parse/queue/mvm/combine
if ! grep -q 'stage="serialize"' "$serve_log"; then
    echo "serve smoke: metrics exposition is missing the serialize stage" >&2
    exit 1
fi
# hybrid dispatch (ISSUE 10): the cost-model router must surface its
# decision counters, its per-substrate calibration histograms, and the
# explicit dispatch pipeline stage in the same exposition
for fam in imka_dispatch_latency_us imka_dispatch_decisions_total; do
    if ! grep -q "$fam" "$serve_log"; then
        echo "serve smoke: metrics exposition is missing $fam" >&2
        exit 1
    fi
done
if ! grep -q 'stage="dispatch"' "$serve_log"; then
    echo "serve smoke: metrics exposition is missing the dispatch stage" >&2
    exit 1
fi
rm -f "$serve_log"

# wire-format gate: the bench streams the same sessions through a live
# TCP server in both encodings; the binary frames exist to beat
# newline-JSON on the serving hot path, so a binary row slower than the
# JSON row is a regression (rows are flat {...} objects; keys serialize
# alphabetically, so grep for the discriminator anywhere inside)
wire_tps() { # $1 = path name
    grep -o '{[^{}]*}' BENCH_serve.json | grep "\"path\":\"$1\"" \
        | sed -n 's/.*"tokens_per_s":\([^,}]*\).*/\1/p'
}
json_tps="$(wire_tps wire_json)"
bin_tps="$(wire_tps wire_binary)"
if [ -z "$json_tps" ] || [ -z "$bin_tps" ]; then
    echo "serve smoke: BENCH_serve.json is missing a wire_json/wire_binary row" >&2
    exit 1
fi
if ! awk -v j="$json_tps" -v b="$bin_tps" 'BEGIN { exit !(b + 0 >= j + 0) }'; then
    echo "serve smoke: binary wire row ($bin_tps tokens/s) is slower than JSON ($json_tps tokens/s)" >&2
    exit 1
fi
echo "serve smoke: wire formats ok (binary $bin_tps tokens/s >= json $json_tps tokens/s)"

# hybrid-dispatch gate: the auto row routes every append through the
# fleet::dispatch cost model; routing overhead must not eat the win, so
# auto throughput may trail the best forced substrate by at most 5%
auto_tps="$(wire_tps auto)"
dig_tps="$(wire_tps digital)"
ana_tps="$(wire_tps analog)"
if [ -z "$auto_tps" ] || [ -z "$dig_tps" ] || [ -z "$ana_tps" ]; then
    echo "serve smoke: BENCH_serve.json is missing an auto/digital/analog row" >&2
    exit 1
fi
if ! awk -v a="$auto_tps" -v d="$dig_tps" -v an="$ana_tps" \
    'BEGIN { best = (d + 0 > an + 0) ? d + 0 : an + 0; exit !(a + 0 >= 0.95 * best) }'; then
    echo "serve smoke: auto dispatch ($auto_tps tokens/s) trails the best forced substrate (digital $dig_tps, analog $ana_tps) by more than 5%" >&2
    exit 1
fi
echo "serve smoke: hybrid dispatch ok (auto $auto_tps tokens/s vs digital $dig_tps / analog $ana_tps)"

# regression diff against the committed previous run (tolerant of a
# missing baseline on fresh clones — see scripts/bench_compare)
echo "== bench_compare (BENCH_serve.json vs committed baseline) =="
scripts/bench_compare BENCH_serve.json

# chaos/soak smoke: a seed-replayable fault schedule (kill + flicker
# faults, drains, drift jumps, programming failures, autoscale surge)
# against the live control plane under concurrent mixed traffic, with
# fleet-wide invariants checked after every step. The gates are the
# machine-readable artifact — BENCH_chaos.json must report zero
# invariant violations and zero SLO alerts still firing at exit — and
# the final metrics exposition, whose canary-accuracy alert gauge must
# be present and must not read 2 (firing): the backbone drift jump is
# required to trip the accuracy alert, and recalibration is required
# to resolve it before the run ends.
echo "== bench_chaos smoke (fault schedule + invariant checks) =="
chaos_log="$(mktemp)"
IMKA_BENCH_CHAOS_SMOKE=1 cargo bench --bench bench_chaos | tee "$chaos_log"
if ! grep -q '"invariant_violations":0' BENCH_chaos.json; then
    echo "chaos smoke: invariant violations reported in BENCH_chaos.json" >&2
    exit 1
fi
if ! grep -q '"alerts_firing_at_exit":0' BENCH_chaos.json; then
    echo "chaos smoke: an SLO alert was still firing when the run ended" >&2
    exit 1
fi
if ! grep -q 'imka_alert_state{rule="canary_accuracy"' "$chaos_log"; then
    echo "chaos smoke: exposition is missing the canary_accuracy alert-state gauge" >&2
    exit 1
fi
if grep 'imka_alert_state{rule="canary_accuracy"' "$chaos_log" | grep -qE ' 2$'; then
    echo "chaos smoke: canary accuracy alert still firing in the final exposition" >&2
    exit 1
fi
rm -f "$chaos_log"

if [ "${SKIP_CLIPPY:-0}" != "1" ]; then
    if cargo clippy --version >/dev/null 2>&1; then
        echo "== cargo clippy --all-targets -- -D warnings =="
        cargo clippy --all-targets -- -D warnings
    else
        echo "clippy not installed; skipping lint (set SKIP_CLIPPY=1 to silence)"
    fi
fi

if [ "${SKIP_FMT:-0}" != "1" ]; then
    if command -v rustfmt >/dev/null 2>&1; then
        echo "== cargo fmt --check =="
        cargo fmt --check
    else
        echo "rustfmt not installed; skipping format check (set SKIP_FMT=1 to silence)"
    fi
fi

echo "tier-1 gate passed"
