//! Quickstart: the paper's core loop in ~60 lines.
//!
//! 1. generate a (synthetic) nonlinear classification dataset,
//! 2. sample a random-feature mapping Ω (ORF),
//! 3. fit a ridge classifier on FP-32 feature maps,
//! 4. program Ω onto the simulated AIMC chip and evaluate the same
//!    classifier on feature maps computed *in analog*,
//! 5. compare accuracies (the paper's <1% delta claim).
//!
//! Run: cargo run --release --example quickstart

use imka::aimc::Chip;
use imka::config::ChipConfig;
use imka::datasets::{load_uci, UciName};
use imka::features::maps::{feature_map, postprocess};
use imka::features::sampler::{sample_omega, Sampler};
use imka::kernels::Kernel;
use imka::linalg::Mat;
use imka::ridge::RidgeClassifier;
use imka::util::Rng;

fn main() -> imka::Result<()> {
    let mut rng = Rng::new(0);

    // 1. data: magic04-like telescope benchmark (binary, d = 10)
    let ds = load_uci(UciName::Magic04, 0, 0.05);
    let d = ds.d();
    println!("dataset: {} ({} train / {} test, d={d})", ds.name, ds.train_x.rows, ds.test_x.rows);

    // bandwidth-scaled inputs for the RBF kernel (see DESIGN.md)
    let scale = 1.0 / (d as f32).sqrt();
    let mut xtr = ds.train_x.clone();
    xtr.scale(scale);
    let mut xte = ds.test_x.clone();
    xte.scale(scale);

    // 2. Ω: orthogonal random features at the paper's operating point
    //    (log2(D/d) = 5 -> m = 16 d for the RBF kernel)
    let m = 16 * d;
    let omega = sample_omega(Sampler::Orf, d, m, &mut rng);
    println!("mapping: RBF kernel, ORF, m={m} (D={})", 2 * m);

    // 3. FP-32 pipeline: z(x) -> ridge (the paper trains in FP-32 only)
    let ztr = feature_map(Kernel::Rbf, &xtr, &omega);
    let clf = RidgeClassifier::fit(&ztr, &ds.train_y, ds.classes, 0.5)?;
    let acc_fp = clf.accuracy(&feature_map(Kernel::Rbf, &xte, &omega), &ds.test_y);

    // 4. analog pipeline: program Ω on the chip (GDP program-and-verify),
    //    run the projection in-memory, post-process digitally
    let mut chip = Chip::new(ChipConfig::default(), 7);
    let handle = chip.program_matrix("omega", &omega, &xtr, 1)?;
    let stats = &chip.program_stats(&handle).unwrap()[0];
    println!(
        "programmed {} tile(s): rms weight error {:.4} -> {:.4} after GDP",
        chip.cores_used(),
        stats.rms_initial,
        stats.rms_final
    );
    let u = chip.matmul(&handle, &xte)?; // in-memory MVM (noisy)
    let z_hw = postprocess(Kernel::Rbf, &u, Some(&xte));
    let acc_hw = clf.accuracy(&z_hw, &ds.test_y);

    // 5. the paper's claim: accuracy loss below ~1%
    println!("\naccuracy FP-32:  {acc_fp:.4}");
    println!("accuracy AIMC:   {acc_hw:.4}");
    println!("delta:           {:+.4} (paper: < 0.01 on average)", acc_fp - acc_hw);

    // bonus: a linear classifier on raw inputs, to show the kernel matters
    let lin = RidgeClassifier::fit(&ds.train_x, &ds.train_y, ds.classes, 0.5)?;
    println!(
        "linear baseline: {:.4} (kernel features add {:+.4})",
        lin.accuracy(&ds.test_x, &ds.test_y),
        acc_fp - lin.accuracy(&ds.test_x, &ds.test_y)
    );
    let _unused: Option<Mat> = None; // keep Mat import for doc clarity
    Ok(())
}
