//! Kernelized-attention walkthrough (the Fig. 3 story):
//!
//! 1. extract Q/K from the trained Performer's first layer,
//! 2. approximate its softmax attention with FAVOR+ features at growing m,
//! 3. run the feature projection digitally and on the simulated chip,
//! 4. report attention-matrix error and the FLOP fraction offloaded.
//!
//! Run: cargo run --release --example attention_approx

use imka::attention::{attention_matrix_error, Projection};
use imka::config::ChipConfig;
use imka::energy::flops::attention_offload_fraction;
use imka::experiments::fig3::extract_qk;
use imka::features::sampler::{sample_omega, Sampler};
use imka::linalg::Mat;
use imka::runtime::ModelBundle;
use imka::util::Rng;

fn main() -> imka::Result<()> {
    let dir = std::path::Path::new("artifacts");
    let (q, k) = match ModelBundle::load(dir, "weights_pattern.npz", "testset_pattern.npz") {
        Ok(bundle) => {
            println!("Q/K extracted from the trained Performer (layer 0, head 0)");
            extract_qk(&bundle, 96)?
        }
        Err(_) => {
            println!("artifacts missing -> random Q/K (run `make artifacts` for the real thing)");
            let mut rng = Rng::new(1);
            let mut q = Mat::randn(96, 16, &mut rng);
            q.scale(0.6);
            let mut k = Mat::randn(96, 16, &mut rng);
            k.scale(0.6);
            (q, k)
        }
    };
    let d = q.cols;
    let chip = ChipConfig::default();
    println!("L={}, d_head={d}\n", q.rows);
    println!("{:>6} {:>12} {:>12} {:>10} {:>14}", "m", "err FP32", "err AIMC", "gap", "attn offload");
    for m in [d / 2, d, 2 * d, 4 * d, 8 * d] {
        let mut e_fp = 0.0;
        let mut e_hw = 0.0;
        let seeds = 5;
        for s in 0..seeds {
            let mut rng = Rng::new(10 + s);
            let omega = sample_omega(Sampler::Orf, d, m.max(2), &mut rng);
            e_fp += attention_matrix_error(&q, &k, &omega, Projection::Fp32, &chip, &mut rng)?;
            e_hw += attention_matrix_error(&q, &k, &omega, Projection::Analog, &chip, &mut rng)?;
        }
        e_fp /= seeds as f64;
        e_hw /= seeds as f64;
        let offload = attention_offload_fraction(q.rows, d, m, 2);
        println!(
            "{:>6} {:>12.4} {:>12.4} {:>+10.4} {:>13.1}%",
            m,
            e_fp,
            e_hw,
            e_hw - e_fp,
            100.0 * offload
        );
    }
    println!("\nthe paper's Fig. 3b shape: error falls with m; the analog path sits slightly above FP-32 with a ~constant gap, while 1/3-1/2 of the attention FLOPs move on-chip.");
    Ok(())
}
