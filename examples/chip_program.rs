//! Chip-level walkthrough: what the AIMC substrate actually simulates.
//!
//! Programs a mapping matrix tile-by-tile, shows GDP program-and-verify
//! convergence, drift with/without compensation, ADC saturation behaviour,
//! and replication-based throughput scaling.
//!
//! Run: cargo run --release --example chip_program

use imka::aimc::{Chip, Emulator};
use imka::config::ChipConfig;
use imka::energy::{aimc_effective_tops, Device};
use imka::linalg::{matmul, Mat};
use imka::util::stats::rel_fro_error;
use imka::util::Rng;

fn main() -> imka::Result<()> {
    let mut rng = Rng::new(2024);
    let d = 300; // forces a 2-row-block split on 256-row crossbars
    let m = 300; // and a 2-column-block split -> 4 tiles
    let w = Mat::randn(d, m, &mut rng);
    let x_cal = Mat::randn(128, d, &mut rng);
    let x = Mat::randn(32, d, &mut rng);
    let want = matmul(&x, &w);

    println!("== GDP program-and-verify ({}x{} matrix -> 4 tiles)", d, m);
    let cfg = ChipConfig::default();
    let mut chip = Chip::new(cfg.clone(), 1);
    let h = chip.program_matrix("w", &w, &x_cal, 1)?;
    for (i, s) in chip.program_stats(&h).unwrap().iter().enumerate() {
        println!(
            "   tile {i}: rms weight err {:.4} -> {:.4} ({} verify iters)",
            s.rms_initial, s.rms_final, s.iters
        );
    }
    let y = chip.matmul(&h, &x)?;
    println!("   end-to-end MVM error: {:.4}", rel_fro_error(&y.data, &want.data));

    println!("\n== drift at t = 1 hour after programming");
    for (label, comp) in [("compensated (chip affine correction)", true), ("uncompensated", false)] {
        let mut c = ChipConfig::default();
        c.drift_compensation = comp;
        let mut chip = Chip::new(c, 2);
        let h = chip.program_matrix("w", &w, &x_cal, 1)?;
        let y = chip.matmul(&h, &x)?;
        println!("   {label}: MVM error {:.4}", rel_fro_error(&y.data, &want.data));
    }

    println!("\n== input resolution (DAC bits)");
    for bits in [8u32, 6, 4] {
        let mut c = ChipConfig::ideal();
        c.input_bits = bits;
        let mut em = Emulator::program(&w, &c, &mut Rng::new(3));
        let y = em.forward(&x);
        println!("   {bits}-bit inputs: MVM error {:.4}", rel_fro_error(&y.data, &want.data));
    }

    println!("\n== replication & modelled throughput (64-core chip)");
    for r in [1usize, 4, 15] {
        let mut chip = Chip::new(ChipConfig::default(), 4);
        let h = chip.program_matrix("w", &w, &x_cal, r)?;
        let tops = aimc_effective_tops(Device::Aimc.spec().tops, chip.cores_used(), 64);
        println!(
            "   replication {r}: {} cores, modelled {:.1} TOPS, replicas {}",
            chip.cores_used(),
            tops,
            chip.replication(&h)
        );
    }
    println!("\n(peak chip: 64 cores = {:.1} TOPS at {:.1} W — Supp. Note 4)",
        Device::Aimc.spec().tops, Device::Aimc.spec().power_w);
    Ok(())
}
