//! END-TO-END driver: all three layers composed on a real workload.
//!
//! Boots the full stack — AOT artifacts (JAX/Pallas-lowered, compiled via
//! PJRT) + simulated AIMC chip + serving coordinator + TCP server — then
//! replays the held-out test set of the trained Performer as batched TCP
//! requests on both the FP-32 and on-chip-attention paths, and reports
//! accuracy, latency percentiles, throughput, and modelled energy.
//!
//! Requires `make artifacts` (trained model + HLO artifacts).
//!
//! Run: cargo run --release --example e2e_serve [-- --requests N]

use std::sync::mpsc;

use imka::cli::Args;
use imka::config::json::{arr, num, obj, s, Json};
use imka::config::Config;
use imka::coordinator::{Client, Engine, Server};
use imka::datasets::lra;
use imka::util::stats::Summary;
use imka::util::{Rng, Timer};

fn main() -> imka::Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    // examples receive flags directly; give Args the subcommand it expects
    argv.insert(0, "e2e".to_string());
    let args = Args::parse(argv)?;
    let n_requests = args.usize_or("requests", 256)?;
    let mut cfg = Config::default();
    cfg.artifacts_dir = args.str_or("artifacts", "artifacts").to_string();
    cfg.serve.max_wait_us = 1500;
    cfg.serve.max_batch = 32;
    cfg.serve.bind = "127.0.0.1:0".into();

    println!("== booting engine (L3 coordinator + PJRT runtime + chip sim)");
    let engine = Engine::start(&cfg)?;
    let seq_len = engine
        .seq_len()
        .expect("run `make artifacts` first (no trained model found)");
    println!(
        "   chip cores programmed: {}, model loaded: {} (seq_len {seq_len})",
        engine.cores_used(),
        engine.has_model()
    );
    let server = Server::start(engine, &cfg.serve.bind)?;
    println!("== server listening on {}", server.addr);

    // workload: fresh LRA-lite `pattern` sequences (same generator family
    // as the held-out set; labels known for accuracy accounting)
    let mut rng = Rng::new(99);
    let batch = lra::gen_pattern(&mut rng, n_requests, seq_len);

    for mode in ["fp32", "hw_attn"] {
        println!("\n== replaying {n_requests} requests, mode={mode} (4 concurrent clients)");
        let timer = Timer::start();
        let (tx, rx) = mpsc::channel::<(usize, Json)>();
        std::thread::scope(|scope| {
            let n_clients = 4;
            for c in 0..n_clients {
                let tx = tx.clone();
                let addr = server.addr;
                let batch = &batch;
                scope.spawn(move || {
                    let mut client = Client::connect(&addr).expect("connect");
                    let mut i = c;
                    while i < n_requests {
                        let req = obj(vec![
                            ("type", s("performer")),
                            ("mode", s(mode)),
                            (
                                "tokens",
                                arr(batch.row(i).iter().map(|&t| num(t as f64))),
                            ),
                        ]);
                        let resp = client.call(&req).expect("call");
                        tx.send((i, resp)).unwrap();
                        i += n_clients;
                    }
                });
            }
            drop(tx);
            let mut correct = 0usize;
            let mut lat = Summary::new();
            let mut energy_uj = 0.0;
            let mut batch_sizes = Summary::new();
            for (i, resp) in rx {
                assert_eq!(
                    resp.get("ok"),
                    Some(&Json::Bool(true)),
                    "request failed: {resp:?}"
                );
                let label = resp.get("label").unwrap().as_usize().unwrap();
                if label == batch.labels[i] {
                    correct += 1;
                }
                lat.push(resp.get("latency_us").unwrap().as_f64().unwrap());
                energy_uj += resp.get("energy_uj").unwrap().as_f64().unwrap();
                batch_sizes.push(resp.get("batch").unwrap().as_f64().unwrap());
            }
            let wall = timer.elapsed_secs();
            println!("   accuracy:        {:.4}", correct as f64 / n_requests as f64);
            println!(
                "   latency (us):    p50 {:.0}  p95 {:.0}  p99 {:.0}",
                lat.p50(),
                lat.p95(),
                lat.p99()
            );
            println!("   throughput:      {:.1} req/s", n_requests as f64 / wall);
            println!("   mean batch size: {:.1}", batch_sizes.mean());
            println!(
                "   modelled AIMC energy: {:.2} uJ total ({:.3} uJ/req)",
                energy_uj,
                energy_uj / n_requests as f64
            );
        });
    }

    println!("\n== telemetry snapshot");
    for snap in server.engine().telemetry().snapshot() {
        println!(
            "   {:?}: {} reqs, p50 {:.0}us, mean batch {:.1}, {} errors",
            snap.lane, snap.requests, snap.p50_us, snap.mean_batch, snap.errors
        );
    }
    server.shutdown();
    println!("== done");
    Ok(())
}
