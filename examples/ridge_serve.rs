//! Ridge-classification serving: the paper's Fig. 2 pipeline through the
//! *serving* stack instead of the experiment harness.
//!
//! 1. fit a ridge classifier offline on FP-32 feature maps (the paper's
//!    training protocol),
//! 2. boot the coordinator; feature requests stream through the dynamic
//!    batcher to either the fused digital XLA artifact or the simulated
//!    chip + post-processing artifact,
//! 3. the classifier read-out itself runs as the `ridge_predict` XLA
//!    artifact (scores = z @ W on the PJRT client),
//! 4. compare digital vs analog end-to-end accuracy and report telemetry.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example ridge_serve

use imka::config::Config;
use imka::coordinator::{Engine, PathKind, RequestBody, ResponseBody};
use imka::datasets::{load_uci, UciName};
use imka::kernels::Kernel;
use imka::linalg::Mat;
use imka::ridge::RidgeClassifier;
use imka::runtime::{Input, Registry};
use imka::util::Timer;

fn main() -> imka::Result<()> {
    // the serving feature lane is rbf/d=16/m=256 (see the manifest);
    // letter is the paper's d=16 benchmark
    let mut ds = load_uci(UciName::Letter, 0, 0.04);
    let scale = 1.0 / (ds.d() as f32).sqrt(); // bandwidth (DESIGN.md)
    ds.train_x.scale(scale);
    ds.test_x.scale(scale);
    println!(
        "dataset: {} ({} train / {} test, d={}, {} classes)",
        ds.name, ds.train_x.rows, ds.test_x.rows, ds.d(), ds.classes
    );

    let mut cfg = Config::default();
    cfg.artifacts_dir = "artifacts".into();
    cfg.serve.max_wait_us = 1000;
    println!("booting engine...");
    let engine = Engine::start(&cfg)?;
    let sub = engine.submitter();
    let registry = Registry::open(std::path::Path::new("artifacts"))?;

    // The engine programmed its own Omega for the rbf lane; recover the
    // exact FP-32 twin by requesting digital features for the train set
    // (classifier must be fit on the SAME mapping the server applies).
    println!("fitting ridge on served FP-32 feature maps...");
    let t = Timer::start();
    let ztr = serve_features(&sub, &ds.train_x, PathKind::Digital)?;
    let clf = RidgeClassifier::fit(&ztr, &ds.train_y, ds.classes, 0.5)?;
    println!("  fit in {:.1} s (D = {})", t.elapsed_secs(), ztr.cols);

    // classifier read-out as an XLA artifact: scores = z @ W (D=512, C=26)
    let predict = registry.load("ridge_predict_b64_D512_c26")?;
    let n_eval = 256.min(ds.test_x.rows);
    for path in [PathKind::Digital, PathKind::Analog] {
        let t = Timer::start();
        let idx: Vec<usize> = (0..n_eval).collect();
        let xte = ds.test_x.select_rows(&idx);
        let z = serve_features(&sub, &xte, path)?;
        let mut correct = 0;
        let mut i0 = 0;
        while i0 < n_eval {
            let i1 = (i0 + 64).min(n_eval);
            let mut zb = Mat::zeros(64, z.cols);
            for r in i0..i1 {
                zb.row_mut(r - i0).copy_from_slice(z.row(r));
            }
            let scores = predict.run_mat(
                &[Input::from_mat(&zb), Input::from_mat(&clf.w)],
                64,
                ds.classes,
            )?;
            for r in i0..i1 {
                let row = scores.row(r - i0);
                let mut best = 0;
                for j in 1..row.len() {
                    if row[j] > row[best] {
                        best = j;
                    }
                }
                if best == ds.test_y[r] {
                    correct += 1;
                }
            }
            i0 = i1;
        }
        println!(
            "{:<8} path: accuracy {:.4} over {n_eval} samples ({:.2} s incl. serving)",
            path.as_str(),
            correct as f64 / n_eval as f64,
            t.elapsed_secs()
        );
    }

    println!("\ntelemetry:");
    for snap in engine.telemetry().snapshot() {
        println!(
            "  {:?}: {} reqs, p50 {:.0} us, mean batch {:.1}, energy {:.2} uJ",
            snap.lane, snap.requests, snap.p50_us, snap.mean_batch, snap.energy_uj
        );
    }
    engine.shutdown();
    Ok(())
}

/// Stream every row of `x` through the coordinator's feature lane.
fn serve_features(
    sub: &imka::coordinator::Submitter,
    x: &Mat,
    path: PathKind,
) -> imka::Result<Mat> {
    let mut rxs = Vec::with_capacity(x.rows);
    for i in 0..x.rows {
        rxs.push(sub.submit(RequestBody::Features {
            kernel: Kernel::Rbf,
            path,
            x: x.row(i).to_vec(),
        })?);
    }
    let mut out: Option<Mat> = None;
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv()
            .map_err(|_| imka::Error::Coordinator("reply dropped".into()))?;
        match resp.result? {
            ResponseBody::Features(z) => {
                let o = out.get_or_insert_with(|| Mat::zeros(x.rows, z.len()));
                o.row_mut(i).copy_from_slice(&z);
            }
            _ => return Err(imka::Error::Coordinator("wrong body".into())),
        }
    }
    Ok(out.expect("non-empty input"))
}
